"""Static layer: the never-reconfigured substrate (paper §5).

Owns exactly what Coyote v2's static layer owns — the host link, the
reconfiguration controller, and the interrupt plumbing — and nothing else:

  * :class:`TransferEngine` — the XDMA analogue.  Chunked, double-buffered
    host->device upload with device-side offset writes (DMA-at-offset), a
    deliberately word-granular "HWICAP" path for the Table 2 comparison,
    and writeback completion counters.
  * :class:`CompileCache` — the routed-and-locked-checkpoint analogue: XLA
    executables keyed by (name, config, mesh, avals), reused across shell
    reconfigurations (nested build flow, Fig 7b).
  * :class:`InterruptBus` — MSI-X analogue: page faults, reconfiguration
    completions, TLB invalidations and user IRQs all land here.
  * :class:`ReconfigController` — streams "partial bitstreams" (serialized
    artifacts) from disk through the utility channel at full bandwidth.

The static layer routes; it never interprets payloads (paper §3).
"""
from __future__ import annotations

import hashlib
import io
import os
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.credits import Link
from repro.core.interfaces import Completion, CompletionQueue, InterruptQueue, Oper

# Interrupt source ids (paper §5.1 lists these four)
IRQ_PAGE_FAULT = 1
IRQ_RECONFIG_DONE = 2
IRQ_TLB_INVALIDATION = 3
IRQ_USER = 4


# ============================================================ transfers ====
@dataclass
class TransferStats:
    nbytes: int = 0
    seconds: float = 0.0
    chunks: int = 0

    @property
    def mbps(self) -> float:
        return self.nbytes / max(self.seconds, 1e-12) / 1e6


class TransferEngine:
    """Host<->device data movement (XDMA core analogue).

    Three paths, mirroring Table 2's controller comparison:
      * ``upload_word_granular``  — HWICAP analogue: tiny synchronous writes,
        one blocking round-trip per word-burst.
      * ``upload``                — Coyote path: large chunks streamed
        through JAX's async dispatch, device-side offset writes, a single
        sync at the end (double-buffered by the dispatch queue).
      * ``upload_whole``          — single device_put (upper bound).
    """

    def __init__(self, device=None):
        self.device = device or jax.devices()[0]
        self._write_at = jax.jit(
            lambda dst, chunk, off: jax.lax.dynamic_update_slice(
                dst, chunk, (off,)), donate_argnums=(0,))

    # -- HWICAP analogue: word-granular, fully synchronous ------------------
    def upload_word_granular(self, data: np.ndarray, *,
                             word_bytes: int = 4096) -> Tuple[jax.Array, TransferStats]:
        flat = data.reshape(-1).view(np.uint8)
        n = flat.size
        words = max(word_bytes // flat.itemsize, 1)
        t0 = time.perf_counter()
        dst = jnp.zeros((n,), jnp.uint8)
        off = 0
        chunks = 0
        while off < n:
            chunk = jnp.asarray(flat[off:off + words])
            dst = self._write_at(dst, chunk, off)
            dst.block_until_ready()          # sync per word-burst
            off += words
            chunks += 1
        dt = time.perf_counter() - t0
        out = jax.device_put(dst).block_until_ready()
        return out, TransferStats(nbytes=n, seconds=dt, chunks=chunks)

    # -- Coyote ICAP path: streamed chunks, one sync -------------------------
    def upload(self, data: np.ndarray, *,
               chunk_bytes: int = 16 << 20) -> Tuple[jax.Array, TransferStats]:
        flat = np.ascontiguousarray(data).reshape(-1).view(np.uint8)
        n = flat.size
        t0 = time.perf_counter()
        dst = jnp.zeros((n,), jnp.uint8)
        off = 0
        chunks = 0
        while off < n:
            end = min(off + chunk_bytes, n)
            chunk = jnp.asarray(flat[off:end])   # async H2D of this chunk
            dst = self._write_at(dst, chunk, off)  # overlaps with next stage
            off = end
            chunks += 1
        dst.block_until_ready()                  # single completion sync
        dt = time.perf_counter() - t0
        return dst, TransferStats(nbytes=n, seconds=dt, chunks=chunks)

    def upload_whole(self, data: np.ndarray) -> Tuple[jax.Array, TransferStats]:
        t0 = time.perf_counter()
        out = jax.device_put(data)
        out.block_until_ready()
        dt = time.perf_counter() - t0
        return out, TransferStats(nbytes=data.nbytes, seconds=dt, chunks=1)

    def download(self, arr: jax.Array) -> Tuple[np.ndarray, TransferStats]:
        t0 = time.perf_counter()
        out = np.asarray(jax.device_get(arr))
        dt = time.perf_counter() - t0
        return out, TransferStats(nbytes=out.nbytes, seconds=dt, chunks=1)

    # -- pytree migration (the migration channel, §5.1) ----------------------
    def migrate_tree(self, tree, shardings=None, *,
                     donate_stale: bool = True) -> Tuple[Any, TransferStats]:
        """Move a host pytree to device (weights-before-serving migration)."""
        t0 = time.perf_counter()
        if shardings is not None:
            out = jax.device_put(tree, shardings)
        else:
            out = jax.device_put(tree)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        nbytes = sum(x.nbytes for x in jax.tree.leaves(tree))
        return out, TransferStats(nbytes=nbytes, seconds=dt,
                                  chunks=len(jax.tree.leaves(tree)))


# ========================================================== compile cache ==
@dataclass
class CacheEntry:
    compiled: Any
    lower_s: float
    compile_s: float
    hits: int = 0
    key: str = ""


class CompileCache:
    """Executable cache keyed by (name, config-hash, mesh, avals) — the
    'routed & locked checkpoint' a new app links against (paper §4)."""

    def __init__(self):
        self._entries: Dict[str, CacheEntry] = {}
        self._lock = threading.Lock()

    @staticmethod
    def make_key(name: str, config_repr: Any, mesh=None,
                 avals: Any = None) -> str:
        h = hashlib.sha256()
        h.update(name.encode())
        h.update(repr(config_repr).encode())
        if mesh is not None:
            h.update(repr((tuple(mesh.shape.items()),
                           mesh.axis_names)).encode())
        if avals is not None:
            h.update(repr(jax.tree.map(
                lambda a: (tuple(a.shape), str(a.dtype)), avals)).encode())
        return h.hexdigest()[:24]

    def get(self, key: str) -> Optional[CacheEntry]:
        with self._lock:
            e = self._entries.get(key)
            if e is not None:
                e.hits += 1
            return e

    def get_or_build(self, key: str,
                     build: Callable[[], Tuple[Any, float, float]]
                     ) -> Tuple[CacheEntry, bool]:
        """build() -> (compiled, lower_s, compile_s).  Returns (entry, hit)."""
        e = self.get(key)
        if e is not None:
            return e, True
        compiled, lower_s, compile_s = build()
        e = CacheEntry(compiled=compiled, lower_s=lower_s,
                       compile_s=compile_s, key=key)
        with self._lock:
            self._entries[key] = e
        return e, False

    def invalidate(self, key: str) -> None:
        with self._lock:
            self._entries.pop(key, None)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            return {"entries": len(self._entries),
                    "hits": sum(e.hits for e in self._entries.values()),
                    "compile_s_saved": sum(
                        e.hits * (e.lower_s + e.compile_s)
                        for e in self._entries.values())}


# =========================================================== interrupts ====
class InterruptBus:
    """Central MSI-X analogue.  Sources post (slot, irq_type, value); the
    per-vFPGA InterruptQueue fan-out happens here."""

    def __init__(self):
        self._queues: Dict[int, InterruptQueue] = {}
        self.log: List[Tuple[float, int, int, int]] = []
        self._lock = threading.Lock()

    def register(self, slot: int, q: InterruptQueue) -> None:
        with self._lock:
            self._queues[slot] = q

    def post(self, slot: int, irq_type: int, value: int = 0) -> None:
        with self._lock:
            self.log.append((time.perf_counter(), slot, irq_type, value))
            q = self._queues.get(slot)
        if q is not None:
            q.raise_irq((irq_type << 32) | (value & 0xFFFFFFFF))


# ====================================================== reconfig control ===
class ReconfigController:
    """ICAP analogue (paper §5.3, Table 2): streams partial "bitstreams"
    (serialized artifact blobs) from disk into device memory.

    Kernel latency  = deserialize + device upload (the actual reconfig).
    Total latency   = disk read + copy-to-"kernel"-buffer + kernel latency.
    """

    def __init__(self, engine: TransferEngine, bus: InterruptBus):
        self.engine = engine
        self.bus = bus

    @staticmethod
    def write_bitstream(path: str, payload: Any) -> int:
        """Serialize a payload dict ({kind?, arrays?, ...metadata}) into
        the safe npz+JSON container (no pickle)."""
        from repro.core import bitstream as B
        if not isinstance(payload, dict):
            payload = {"value": B.jsonable(payload)}
        kind = payload.get("kind", "raw")
        header = {k: B.jsonable(v) for k, v in payload.items()
                  if k not in ("kind", "arrays")}
        blob = B.encode(kind, header, arrays=payload.get("arrays"))
        with open(path, "wb") as f:
            f.write(blob)
        return len(blob)

    def load_bitstream(self, path: str, *, slot: int = 0,
                       chunk_bytes: int = 16 << 20):
        """Returns (payload, kernel_s, total_s, nbytes).  The blob is
        parsed by the safe container codec; malformed/unknown bitstreams
        raise :class:`repro.core.bitstream.BitstreamError` rather than
        deserializing arbitrary objects."""
        from repro.core import bitstream as B
        t_total0 = time.perf_counter()
        with open(path, "rb") as f:
            blob = f.read()                       # disk -> user space
        staged = bytearray(blob)                  # user -> kernel copy
        t_k0 = time.perf_counter()
        kind, header, arrays = B.decode(bytes(staged))
        payload = dict(header, kind=kind)
        if arrays is not None:
            dev, _ = self.engine.migrate_tree(arrays)
            payload["arrays"] = dev
        t1 = time.perf_counter()
        self.bus.post(slot, IRQ_RECONFIG_DONE, value=len(blob) & 0xFFFFFFFF)
        return payload, (t1 - t_k0), (t1 - t_total0), len(blob)


# ============================================================ the layer ====
class StaticLayer:
    """Host link + reconfig + interrupts; routes everything else upward."""

    def __init__(self, mesh=None, *, pcie_gbps: float = 12e9):
        self.mesh = mesh
        self.engine = TransferEngine()
        self.compile_cache = CompileCache()
        self.interrupts = InterruptBus()
        self.reconfig = ReconfigController(self.engine, self.interrupts)
        # modeled links for the fairness/packetization layer
        self.pcie = Link("pcie", pcie_gbps)
        self.writebacks = CompletionQueue()

    def route_completion(self, ticket: int, tid: int, op: Oper, nbytes: int,
                         t_submit: float, result: Any = None) -> None:
        self.writebacks.complete(Completion(
            ticket=ticket, tid=tid, opcode=op, nbytes=nbytes,
            t_submit=t_submit, t_done=time.perf_counter(), result=result))
