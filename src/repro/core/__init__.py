"""Coyote-JAX core: the paper's three-layer shell in JAX.

Static layer (never reconfigured) / dynamic layer (reconfigurable services)
/ application layer (vFPGA slots + cThreads), with credit-based fair
sharing, run-time reconfiguration, and a unified multi-stream interface.
"""
from repro.core.cthread import Alloc, CThread
from repro.core.faults import (FaultKind, FaultPlan, FaultSpec,
                               InjectedFault)
from repro.core.health import HealthMonitor, Watchdog
from repro.core.interfaces import (AppInterface, Completion, Oper, SgEntry)
from repro.core.migrate import (MigrationError, MigrationReport,
                                RecoveryReport, migrate,
                                recover_tenant_local)
from repro.core.port import (Invocation, Port, PortCapabilities, PortError,
                             PortFuture, PortState, ServicePort, VFpgaPort)
from repro.core.scheduler import ShellScheduler, Tenant
from repro.core.shell import BuildReport, Shell, ShellConfig
from repro.core.static_layer import StaticLayer, TransferEngine
from repro.core.vfpga import AppArtifact, VFpga

__all__ = [
    "Alloc", "CThread", "AppInterface", "Completion", "Oper", "SgEntry",
    "Invocation", "Port", "PortCapabilities", "PortError", "PortFuture",
    "PortState", "ServicePort", "VFpgaPort",
    "FaultKind", "FaultPlan", "FaultSpec", "InjectedFault",
    "HealthMonitor", "Watchdog",
    "BuildReport", "Shell", "ShellConfig", "ShellScheduler", "StaticLayer",
    "Tenant", "TransferEngine", "AppArtifact", "VFpga",
    "MigrationError", "MigrationReport", "RecoveryReport", "migrate",
    "recover_tenant_local",
]
