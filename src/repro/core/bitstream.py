"""Safe, versioned "partial bitstream" container (no pickle).

Layout of a bitstream blob:

    +--------+---------+------------+-------------------+--------------+
    | b"CYBS"| u16 ver | u32 hlen   | JSON header (hlen)| npz payload  |
    +--------+---------+------------+-------------------+--------------+

The JSON header carries all metadata (kind, artifact version, config,
requirements, ...) plus a JSON-encoded *skeleton* of the weight pytree in
which every array leaf is replaced by ``{"__leaf__": i}``; leaf ``i`` is
stored as entry ``a<i>`` of the trailing npz archive (loaded with
``allow_pickle=False``).  Nothing in the format can execute code on load —
the replacement for the previous pickle-based serialization.

The JSON header also carries an ``integrity`` stanza — a blake2b digest
of the npz payload, written by every encode and verified on decode: a
flipped bit anywhere in the payload (or a truncated container) raises
:class:`BitstreamError` instead of restoring corrupt tenant state, and
an integrity stanza naming an algorithm this reader doesn't know is
refused outright rather than skipped.  ``encode_stream``/
``decode_stream`` are the chunked forms: the payload is materialized
once (npz spool) and shipped/consumed as bounded chunks, so a multi-GB
migration container never exists twice in host memory.

Unknown magic, container version, or ``kind`` raise
:class:`BitstreamError` with a clear message instead of deserializing.
"""
from __future__ import annotations

import hashlib
import io
import json
import struct
from typing import Any, Dict, Iterable, Iterator, List, Optional, Tuple

import numpy as np

MAGIC = b"CYBS"
FORMAT_VERSION = 1
# "migration" blobs carry a quiesced tenant's state (page tables, live KV
# payload, CSR/addr-map) for quiesce-and-migrate — see repro.core.migrate
KNOWN_KINDS = ("shell", "app", "raw", "migration")
# payload-digest algorithms this reader implements; a container naming
# anything else is refused (treating it as "no hash" would let a forger
# strip verification by inventing an algo name)
INTEGRITY_KINDS = ("blake2b",)
_DIGEST_SIZE = 32

_HDR = struct.Struct("<HI")         # (format_version, header_len)


class BitstreamError(ValueError):
    """Malformed, unknown-kind, or unknown-version bitstream."""


# ------------------------------------------------------- pytree skeleton ---
def _encode_tree(x: Any, leaves: List[np.ndarray]) -> Any:
    if x is None or isinstance(x, (bool, int, float, str)):
        return x
    if hasattr(x, "__array__") or isinstance(x, (np.ndarray, np.generic)):
        leaves.append(np.asarray(x))
        return {"__leaf__": len(leaves) - 1}
    if isinstance(x, dict):
        if any(not isinstance(k, str) for k in x):
            raise BitstreamError(
                "bitstream trees require string dict keys, got "
                f"{sorted(map(repr, x))[:3]}")
        return {"__dict__": {k: _encode_tree(v, leaves)
                             for k, v in x.items()}}
    if isinstance(x, (list, tuple)):
        tag = "__list__" if isinstance(x, list) else "__tuple__"
        return {tag: [_encode_tree(v, leaves) for v in x]}
    raise BitstreamError(
        f"unsupported type in bitstream tree: {type(x).__name__} "
        "(allowed: arrays, dict/list/tuple, JSON scalars)")


def _decode_tree(x: Any, leaves: Dict[str, np.ndarray]) -> Any:
    if isinstance(x, dict):
        if "__leaf__" in x:
            return leaves[f"a{x['__leaf__']}"]
        if "__dict__" in x:
            return {k: _decode_tree(v, leaves)
                    for k, v in x["__dict__"].items()}
        if "__list__" in x:
            return [_decode_tree(v, leaves) for v in x["__list__"]]
        if "__tuple__" in x:
            return tuple(_decode_tree(v, leaves) for v in x["__tuple__"])
        raise BitstreamError(f"malformed tree node: {sorted(x)}")
    return x


# ------------------------------------------------------------- container ---
def _verify_integrity(doc: Dict[str, Any], digest: str) -> None:
    """Check a computed payload hexdigest against the header stanza.
    Containers written before integrity landed have no stanza and stay
    loadable; a stanza with an algorithm we don't implement is refused."""
    integ = doc.get("integrity")
    if integ is None:
        return
    algo = integ.get("algo")
    if algo not in INTEGRITY_KINDS:
        raise BitstreamError(
            f"unsupported bitstream integrity algo {algo!r} (known: "
            f"{INTEGRITY_KINDS}); refusing to load unverifiable payload")
    if digest != integ.get("digest"):
        raise BitstreamError(
            "bitstream payload integrity check failed (blake2b digest "
            "mismatch — truncated or tampered container)")


def _parse_doc(hjson: bytes) -> Dict[str, Any]:
    try:
        return json.loads(hjson.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise BitstreamError(f"corrupt bitstream header: {e}")


def _check_kind(doc: Dict[str, Any],
                expect_kind: Optional[str]) -> str:
    kind = doc.get("kind")
    if kind not in KNOWN_KINDS:
        raise BitstreamError(
            f"unknown bitstream kind {kind!r} (known: {KNOWN_KINDS}); "
            "refusing to load")
    if expect_kind is not None and kind != expect_kind:
        raise BitstreamError(
            f"expected a {expect_kind!r} bitstream, got {kind!r}")
    return kind


def encode_stream(kind: str, header: Dict[str, Any], arrays: Any = None,
                  *, chunk_bytes: int = 1 << 20) -> Iterator[bytes]:
    """Serialize one bitstream as a chunk generator.

    The npz payload is spooled exactly once; yielded chunks are bounded
    slices of it, so a caller that forwards chunks to a transport never
    holds a second full copy.  The header's ``integrity`` stanza carries
    the blake2b digest of the spooled payload.
    """
    if kind not in KNOWN_KINDS:
        raise BitstreamError(
            f"unknown bitstream kind {kind!r} (known: {KNOWN_KINDS})")
    leaves: List[np.ndarray] = []
    skeleton = _encode_tree(arrays, leaves)
    bio = io.BytesIO()
    np.savez(bio, **{f"a{i}": leaf for i, leaf in enumerate(leaves)})
    payload = bio.getbuffer()
    doc = {"kind": kind, "header": header, "arrays": skeleton,
           "integrity": {
               "algo": "blake2b",
               "digest": hashlib.blake2b(
                   payload, digest_size=_DIGEST_SIZE).hexdigest()}}
    try:
        hjson = json.dumps(doc, sort_keys=True).encode("utf-8")
    except TypeError as e:
        raise BitstreamError(f"bitstream header is not JSON-safe: {e}")
    yield MAGIC + _HDR.pack(FORMAT_VERSION, len(hjson))
    for i in range(0, len(hjson), chunk_bytes):
        yield hjson[i:i + chunk_bytes]
    for i in range(0, len(payload), chunk_bytes):
        yield bytes(payload[i:i + chunk_bytes])


def encode(kind: str, header: Dict[str, Any],
           arrays: Any = None) -> bytes:
    """Serialize one bitstream.  ``header`` must be JSON-serializable;
    ``arrays`` is an optional pytree of array leaves."""
    return b"".join(encode_stream(kind, header, arrays))


def decode_stream(chunks: Iterable[bytes], *,
                  expect_kind: Optional[str] = None
                  ) -> Tuple[str, Dict[str, Any], Any]:
    """Parse a stream of bitstream chunks -> (kind, header, arrays).

    Chunks may split anywhere (byte boundaries carry no meaning).  The
    payload is spooled into one buffer and blake2b-hashed incrementally
    as chunks arrive — the full container is never assembled.
    """
    it = iter(chunks)
    pre = len(MAGIC) + _HDR.size
    buf = bytearray()
    exhausted = False
    while len(buf) < pre and not exhausted:
        try:
            buf.extend(next(it))
        except StopIteration:
            exhausted = True
    if len(buf) < pre or bytes(buf[:len(MAGIC)]) != MAGIC:
        raise BitstreamError(
            "not a Coyote bitstream (bad magic; refusing to deserialize "
            "legacy pickle blobs)")
    ver, hlen = _HDR.unpack_from(buf, len(MAGIC))
    if ver > FORMAT_VERSION:
        raise BitstreamError(
            f"bitstream container version {ver} is newer than this "
            f"reader (supports <= {FORMAT_VERSION}); refusing to load")
    while len(buf) < pre + hlen and not exhausted:
        try:
            buf.extend(next(it))
        except StopIteration:
            exhausted = True
    if len(buf) < pre + hlen:
        raise BitstreamError("truncated bitstream header")
    doc = _parse_doc(bytes(buf[pre:pre + hlen]))
    kind = _check_kind(doc, expect_kind)
    h = hashlib.blake2b(digest_size=_DIGEST_SIZE)
    payload = io.BytesIO()
    tail = memoryview(buf)[pre + hlen:]
    h.update(tail)
    payload.write(tail)
    for c in it:
        h.update(c)
        payload.write(c)
    _verify_integrity(doc, h.hexdigest())
    arrays = None
    if doc.get("arrays") is not None:
        payload.seek(0)
        npz = np.load(payload, allow_pickle=False)
        arrays = _decode_tree(doc["arrays"], npz)
    return kind, doc.get("header", {}), arrays


def decode(blob: bytes, *, expect_kind: Optional[str] = None
           ) -> Tuple[str, Dict[str, Any], Any]:
    """Parse a bitstream blob -> (kind, header, arrays).

    Rejects bad magic, container versions newer than this reader,
    unknown/unexpected kinds, and (for containers carrying an integrity
    stanza) payload digest mismatches with a :class:`BitstreamError`.
    """
    if len(blob) < len(MAGIC) + _HDR.size or blob[:len(MAGIC)] != MAGIC:
        raise BitstreamError(
            "not a Coyote bitstream (bad magic; refusing to deserialize "
            "legacy pickle blobs)")
    ver, hlen = _HDR.unpack_from(blob, len(MAGIC))
    if ver > FORMAT_VERSION:
        raise BitstreamError(
            f"bitstream container version {ver} is newer than this "
            f"reader (supports <= {FORMAT_VERSION}); refusing to load")
    off = len(MAGIC) + _HDR.size
    doc = _parse_doc(blob[off:off + hlen])
    kind = _check_kind(doc, expect_kind)
    payload = memoryview(blob)[off + hlen:]
    _verify_integrity(doc, hashlib.blake2b(
        payload, digest_size=_DIGEST_SIZE).hexdigest())
    arrays = None
    if doc.get("arrays") is not None:
        npz = np.load(io.BytesIO(payload), allow_pickle=False)
        arrays = _decode_tree(doc["arrays"], npz)
    return kind, doc.get("header", {}), arrays


def jsonable(x: Any) -> Any:
    """Best-effort JSON projection for free-form config metadata
    (``config_repr`` etc.): dataclasses become dicts, unknown objects
    their repr.  Lossy by design — config_repr is cache-key material,
    not executable state."""
    import dataclasses
    if dataclasses.is_dataclass(x) and not isinstance(x, type):
        return {k: jsonable(v)
                for k, v in dataclasses.asdict(x).items()}
    if isinstance(x, dict):
        return {str(k): jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [jsonable(v) for v in x]
    if x is None or isinstance(x, (bool, int, float, str)):
        return x
    return repr(x)
