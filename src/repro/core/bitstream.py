"""Safe, versioned "partial bitstream" container (no pickle).

Layout of a bitstream blob:

    +--------+---------+------------+-------------------+--------------+
    | b"CYBS"| u16 ver | u32 hlen   | JSON header (hlen)| npz payload  |
    +--------+---------+------------+-------------------+--------------+

The JSON header carries all metadata (kind, artifact version, config,
requirements, ...) plus a JSON-encoded *skeleton* of the weight pytree in
which every array leaf is replaced by ``{"__leaf__": i}``; leaf ``i`` is
stored as entry ``a<i>`` of the trailing npz archive (loaded with
``allow_pickle=False``).  Nothing in the format can execute code on load —
the replacement for the previous pickle-based serialization.

Unknown magic, container version, or ``kind`` raise
:class:`BitstreamError` with a clear message instead of deserializing.
"""
from __future__ import annotations

import io
import json
import struct
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

MAGIC = b"CYBS"
FORMAT_VERSION = 1
# "migration" blobs carry a quiesced tenant's state (page tables, live KV
# payload, CSR/addr-map) for quiesce-and-migrate — see repro.core.migrate
KNOWN_KINDS = ("shell", "app", "raw", "migration")

_HDR = struct.Struct("<HI")         # (format_version, header_len)


class BitstreamError(ValueError):
    """Malformed, unknown-kind, or unknown-version bitstream."""


# ------------------------------------------------------- pytree skeleton ---
def _encode_tree(x: Any, leaves: List[np.ndarray]) -> Any:
    if x is None or isinstance(x, (bool, int, float, str)):
        return x
    if hasattr(x, "__array__") or isinstance(x, (np.ndarray, np.generic)):
        leaves.append(np.asarray(x))
        return {"__leaf__": len(leaves) - 1}
    if isinstance(x, dict):
        if any(not isinstance(k, str) for k in x):
            raise BitstreamError(
                "bitstream trees require string dict keys, got "
                f"{sorted(map(repr, x))[:3]}")
        return {"__dict__": {k: _encode_tree(v, leaves)
                             for k, v in x.items()}}
    if isinstance(x, (list, tuple)):
        tag = "__list__" if isinstance(x, list) else "__tuple__"
        return {tag: [_encode_tree(v, leaves) for v in x]}
    raise BitstreamError(
        f"unsupported type in bitstream tree: {type(x).__name__} "
        "(allowed: arrays, dict/list/tuple, JSON scalars)")


def _decode_tree(x: Any, leaves: Dict[str, np.ndarray]) -> Any:
    if isinstance(x, dict):
        if "__leaf__" in x:
            return leaves[f"a{x['__leaf__']}"]
        if "__dict__" in x:
            return {k: _decode_tree(v, leaves)
                    for k, v in x["__dict__"].items()}
        if "__list__" in x:
            return [_decode_tree(v, leaves) for v in x["__list__"]]
        if "__tuple__" in x:
            return tuple(_decode_tree(v, leaves) for v in x["__tuple__"])
        raise BitstreamError(f"malformed tree node: {sorted(x)}")
    return x


# ------------------------------------------------------------- container ---
def encode(kind: str, header: Dict[str, Any],
           arrays: Any = None) -> bytes:
    """Serialize one bitstream.  ``header`` must be JSON-serializable;
    ``arrays`` is an optional pytree of array leaves."""
    if kind not in KNOWN_KINDS:
        raise BitstreamError(
            f"unknown bitstream kind {kind!r} (known: {KNOWN_KINDS})")
    leaves: List[np.ndarray] = []
    skeleton = _encode_tree(arrays, leaves)
    doc = {"kind": kind, "header": header, "arrays": skeleton}
    try:
        hjson = json.dumps(doc, sort_keys=True).encode("utf-8")
    except TypeError as e:
        raise BitstreamError(f"bitstream header is not JSON-safe: {e}")
    bio = io.BytesIO()
    np.savez(bio, **{f"a{i}": leaf for i, leaf in enumerate(leaves)})
    return MAGIC + _HDR.pack(FORMAT_VERSION, len(hjson)) + hjson \
        + bio.getvalue()


def decode(blob: bytes, *, expect_kind: Optional[str] = None
           ) -> Tuple[str, Dict[str, Any], Any]:
    """Parse a bitstream blob -> (kind, header, arrays).

    Rejects bad magic, container versions newer than this reader, and
    unknown/unexpected kinds with a :class:`BitstreamError`.
    """
    if len(blob) < len(MAGIC) + _HDR.size or blob[:len(MAGIC)] != MAGIC:
        raise BitstreamError(
            "not a Coyote bitstream (bad magic; refusing to deserialize "
            "legacy pickle blobs)")
    ver, hlen = _HDR.unpack_from(blob, len(MAGIC))
    if ver > FORMAT_VERSION:
        raise BitstreamError(
            f"bitstream container version {ver} is newer than this "
            f"reader (supports <= {FORMAT_VERSION}); refusing to load")
    off = len(MAGIC) + _HDR.size
    try:
        doc = json.loads(blob[off:off + hlen].decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise BitstreamError(f"corrupt bitstream header: {e}")
    kind = doc.get("kind")
    if kind not in KNOWN_KINDS:
        raise BitstreamError(
            f"unknown bitstream kind {kind!r} (known: {KNOWN_KINDS}); "
            "refusing to load")
    if expect_kind is not None and kind != expect_kind:
        raise BitstreamError(
            f"expected a {expect_kind!r} bitstream, got {kind!r}")
    arrays = None
    if doc.get("arrays") is not None:
        npz = np.load(io.BytesIO(blob[off + hlen:]), allow_pickle=False)
        arrays = _decode_tree(doc["arrays"], npz)
    return kind, doc.get("header", {}), arrays


def jsonable(x: Any) -> Any:
    """Best-effort JSON projection for free-form config metadata
    (``config_repr`` etc.): dataclasses become dicts, unknown objects
    their repr.  Lossy by design — config_repr is cache-key material,
    not executable state."""
    import dataclasses
    if dataclasses.is_dataclass(x) and not isinstance(x, type):
        return {k: jsonable(v)
                for k, v in dataclasses.asdict(x).items()}
    if isinstance(x, dict):
        return {str(k): jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [jsonable(v) for v in x]
    if x is None or isinstance(x, (bool, int, float, str)):
        return x
    return repr(x)
