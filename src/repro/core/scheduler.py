"""Multi-tenant shell scheduler: async submission + weighted-credit QoS.

Replaces the synchronous per-slot ``Shell.kick()`` drain loop with an
event-driven subsystem in front of the link arbiter:

  * **Async intake** — cThreads on any vFPGA slot enqueue scatter-gather
    work concurrently; a single scheduler thread (the "shell datapath
    clock") ingests, batches, and issues it.  Callers synchronize on the
    completion queues exactly as before.
  * **Coalescing** — consecutive small SG entries on the same
    (slot, stream) are merged into one packet-sized batch before hitting
    the arbiter, so tiny descriptors stop costing a full arbiter visit
    each.  Batches never span streams and never reorder entries: each
    (slot, stream) is a FIFO end to end.
  * **Weighted credits** — every tenant owns a credit account sized by its
    weight; batches acquire one credit per packet before entering the
    arbiter and release on completion, so an over-subscribed tenant stalls
    itself, never the link (back-pressure containment, paper §7.2).
  * **Weighted bandwidth** — the :class:`~repro.core.credits.WeightedRRArbiter`
    serves each (slot, stream) queue with its tenant's weight, split evenly
    across the tenant's active queues so a tenant's share is set by its
    weight, not its stream count.
  * **Per-tenant QoS stats** — byte shares, weighted/unweighted Jain's
    fairness, mean submit→complete latency, and throughput, surfaced
    through ``Shell.status()["scheduler"]``.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional, Set, Tuple

from repro.core import credits as C
from repro.core.interfaces import Completion, SgEntry

DEFAULT_TENANT_PREFIX = "tenant"


@dataclass
class Tenant:
    """One bandwidth principal: a weight, a credit account, QoS counters."""
    name: str
    weight: float = 1.0
    credits: C.CreditAccount = None          # set by the scheduler
    submissions: int = 0
    completions: int = 0
    pending: int = 0                         # accepted, not yet completed
    intake_stalls: int = 0                   # submitter back-pressure events
    batches: int = 0
    bytes_done: int = 0
    lat_sum_s: float = 0.0
    t_first_submit: float = 0.0
    t_last_done: float = 0.0

    def stats(self) -> Dict[str, float]:
        span = max(self.t_last_done - self.t_first_submit, 1e-12)
        return {
            "weight": self.weight,
            "submissions": self.submissions,
            "completions": self.completions,
            "batches": self.batches,
            "bytes": self.bytes_done,
            "mean_latency_s": self.lat_sum_s / max(self.completions, 1),
            "throughput_bps": self.bytes_done / span if self.bytes_done
            else 0.0,
            "credit_capacity": self.credits.capacity if self.credits else 0,
            "credit_stalls": self.credits.stalls if self.credits else 0,
            "intake_stalls": self.intake_stalls,
        }


@dataclass
class _Submission:
    slot: int
    stream: int
    ticket: int
    sg: SgEntry
    tenant: Tenant
    nbytes: int
    t_submit: float
    execute: Optional[Callable[[int, SgEntry], Completion]] = None
    complete: Optional[Callable[[Completion], None]] = None
    done_event: Optional[threading.Event] = None
    on_done: Optional[Callable[[], None]] = None


@dataclass
class _Batch:
    tenant: Tenant
    requester: str
    subs: List[_Submission]
    nbytes: int
    npkts: int


class ShellScheduler:
    """Event-driven multi-tenant scheduler in front of a weighted arbiter."""

    def __init__(self, arbiter: C.WeightedRRArbiter, *,
                 packet_bytes: int = C.DEFAULT_PACKET_BYTES,
                 stream_depth: int = 64,
                 coalesce: bool = True,
                 max_batch_entries: int = 16,
                 max_pending_per_tenant: Optional[int] = None):
        self.arbiter = arbiter
        self.packet_bytes = packet_bytes
        self.stream_depth = stream_depth
        self.coalesce = coalesce
        self.max_batch_entries = max_batch_entries
        # submitter-side back-pressure bound (paper §7.2: an over-subscribed
        # tenant stalls ITSELF): submissions beyond this block the caller
        # until completions free room.  pause() exempts itself — it exists
        # precisely to build up saturation backlogs deterministically.
        self.max_pending_per_tenant = (max_pending_per_tenant
                                       if max_pending_per_tenant is not None
                                       else 64 * stream_depth)

        self._tenants: Dict[str, Tenant] = {}
        self._slot_tenant: Dict[int, str] = {}
        # requester name -> tenant, for weight rebalancing across a
        # tenant's active (slot, stream) queues
        self._tenant_requesters: Dict[str, Set[str]] = {}

        self._intake: Deque[_Submission] = deque()
        self._pend: Dict[Tuple[int, int], Deque[_Submission]] = {}
        self._pend_order: List[Tuple[int, int]] = []

        self._lock = threading.Lock()
        self._work_cv = threading.Condition(self._lock)
        self._idle_cv = threading.Condition(self._lock)
        self._inflight = 0
        self._paused = False
        self._stop = False
        self._worker: Optional[threading.Thread] = None

        self.batches_issued = 0
        self.entries_coalesced = 0          # entries that rode in a batch >1

    # ------------------------------------------------------------ tenants --
    def register_tenant(self, name: str, weight: float = 1.0) -> Tenant:
        """Create/update a tenant.  Credit capacity scales with weight so a
        heavier tenant may keep proportionally more packets in flight."""
        if weight <= 0:
            raise ValueError(f"tenant weight must be > 0, got {weight}")
        with self._lock:
            t = self._tenants.get(name)
            if t is None:
                t = Tenant(name=name, weight=weight)
                t.credits = C.CreditAccount(
                    max(1, int(round(self.stream_depth * weight))))
                self._tenants[name] = t
                self._tenant_requesters.setdefault(name, set())
            elif t.weight != weight:
                t.weight = weight
                t.credits = C.CreditAccount(
                    max(1, int(round(self.stream_depth * weight))))
                self._rebalance_weights(name)
        return t

    def bind_slot(self, slot: int, tenant: str) -> None:
        """Route all submissions from a vFPGA slot to the named tenant."""
        if tenant not in self._tenants:
            self.register_tenant(tenant)
        with self._lock:
            self._slot_tenant[slot] = tenant

    def tenant_of(self, slot: int) -> Tenant:
        with self._lock:
            name = self._slot_tenant.get(slot)
        if name is None:
            name = f"{DEFAULT_TENANT_PREFIX}{slot}"
            self._tenant_by_name(name)
            self.bind_slot(slot, name)
        return self._tenants[name]

    def _tenant_by_name(self, name: str) -> Tenant:
        """Get-or-create WITHOUT touching an existing tenant's weight
        (register_tenant with the default weight would reset it)."""
        with self._lock:
            t = self._tenants.get(name)
        if t is not None:
            return t
        return self.register_tenant(name)

    def tenants(self) -> Dict[str, Tenant]:
        with self._lock:
            return dict(self._tenants)

    def tenant_pending(self, name: str) -> int:
        """Accepted-but-uncompleted submissions for a tenant — surfaced
        by ``ServingEngine.run()`` stats (``io_pending``) so async
        decode-IO billing that failed to drain is visible, never silent."""
        with self._lock:
            t = self._tenants.get(name)
            return t.pending if t is not None else 0

    def _rebalance_weights(self, tenant_name: str,
                           extra: Optional[str] = None) -> None:
        """Split a tenant's weight evenly over its BACKLOGGED requesters so
        its link share tracks its weight regardless of how many
        (slot, stream) queues it currently fans out on.  Requesters whose
        arbiter queue has drained stop diluting the share (they are
        re-included by the rebalance accompanying their next batch).
        Caller must hold self._lock."""
        t = self._tenants[tenant_name]
        reqs = self._tenant_requesters.get(tenant_name, set())
        active = {r for r in reqs if self.arbiter.backlogged(r)}
        if extra is not None:
            active.add(extra)
        if not active:
            return
        per = t.weight / len(active)
        for r in active:
            self.arbiter.set_weight(r, per)

    # ------------------------------------------------------------- intake --
    def submit(self, *, slot: int, stream: int, ticket: int, sg: SgEntry,
               execute: Callable[[int, SgEntry], Completion],
               complete: Callable[[Completion], None],
               tenant: Optional[str] = None) -> None:
        """Enqueue one SG descriptor (any thread; blocks only when the
        tenant exceeds its pending bound — submitter-side back-pressure)."""
        ten = (self._tenant_by_name(tenant) if tenant is not None
               else self.tenant_of(slot))
        sub = _Submission(slot=slot, stream=stream, ticket=ticket, sg=sg,
                          tenant=ten, nbytes=max(sg.length, 1),
                          t_submit=time.perf_counter(),
                          execute=execute, complete=complete)
        self._enqueue(sub)

    def submit_io(self, nbytes: int, *, slot: int = 0, stream: int = 0,
                  tenant: Optional[str] = None, tag: str = "io",
                  wait: bool = False,
                  timeout: Optional[float] = None,
                  on_done: Optional[Callable[[], None]] = None
                  ) -> threading.Event:
        """Enqueue a raw transfer with no SG execution behind it — the path
        the serving engine uses to push its decode-step I/O through the
        shared link under this tenant's QoS weight.  ``on_done`` (used by
        the Port layer to resolve futures) fires once the bytes clear the
        link, on whichever thread completed them."""
        ten = (self._tenant_by_name(tenant) if tenant is not None
               else self.tenant_of(slot))
        if (self._worker is not None
                and threading.current_thread() is self._worker):
            # Re-entrant submission from inside an executing batch (e.g. a
            # serving app's decode loop running under execute_sg): waiting
            # on our own thread would deadlock, so bill the link and the
            # tenant inline.  Bytes still land in the arbiter's delivered
            # table so tenant totals and arbiter totals stay reconciled.
            t_sub = time.perf_counter()
            requester = f"{ten.name}/vfpga{slot}.s{stream}:inline"
            with self._lock:
                if ten.t_first_submit == 0.0:
                    ten.t_first_submit = t_sub
                ten.submissions += 1
            self.arbiter.link.transfer(max(nbytes, 1), src=requester,
                                       tag=tag)
            self.arbiter.delivered[requester] = (
                self.arbiter.delivered.get(requester, 0) + max(nbytes, 1))
            now = time.perf_counter()
            ten.completions += 1
            ten.bytes_done += max(nbytes, 1)
            ten.lat_sum_s += now - t_sub
            ten.t_last_done = now
            ev = threading.Event()
            ev.set()
            if on_done is not None:
                on_done()
            return ev
        sg = SgEntry(length=max(nbytes, 1), src_stream=stream,
                     meta={"tag": tag})
        sub = _Submission(slot=slot, stream=stream, ticket=-1, sg=sg,
                          tenant=ten, nbytes=max(nbytes, 1),
                          t_submit=time.perf_counter(),
                          done_event=threading.Event(), on_done=on_done)
        self._enqueue(sub)
        if wait:
            sub.done_event.wait(timeout=timeout)
        return sub.done_event

    def _enqueue(self, sub: _Submission) -> None:
        on_worker = (self._worker is not None
                     and threading.current_thread() is self._worker)
        with self._lock:
            # submitter-side back-pressure: an over-subscribed tenant
            # stalls itself, never the link or other tenants.  Skipped
            # while paused (pause() exists to build saturation backlogs)
            # and on the worker thread (it is the one draining).
            while (not self._paused and not on_worker
                   and sub.tenant.pending >= self.max_pending_per_tenant):
                sub.tenant.intake_stalls += 1
                self._idle_cv.wait(timeout=0.25)
            if sub.tenant.t_first_submit == 0.0:
                sub.tenant.t_first_submit = sub.t_submit
            sub.tenant.submissions += 1
            sub.tenant.pending += 1
            self._inflight += 1
            self._intake.append(sub)
            self._ensure_worker_locked()
            self._work_cv.notify_all()

    # ------------------------------------------------------- flow control --
    def pause(self) -> None:
        """Hold scheduling (submissions still accepted).  Lets callers build
        up saturation demand before any byte moves — deterministic QoS
        benchmarks depend on this."""
        with self._lock:
            self._paused = True

    def resume(self) -> None:
        with self._lock:
            self._paused = False
            self._work_cv.notify_all()

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until every accepted submission has completed."""
        deadline = (None if timeout is None
                    else time.perf_counter() + timeout)
        with self._lock:
            if self._paused:
                self._paused = False
            self._ensure_worker_locked()
            self._work_cv.notify_all()
            while self._inflight > 0:
                remaining = (None if deadline is None
                             else deadline - time.perf_counter())
                if remaining is not None and remaining <= 0:
                    return False
                self._idle_cv.wait(timeout=remaining if remaining else 0.25)
            return True

    def close(self) -> None:
        with self._lock:
            self._stop = True
            self._work_cv.notify_all()
        if self._worker is not None:
            self._worker.join(timeout=2.0)

    # ------------------------------------------------------------- worker --
    def _ensure_worker_locked(self) -> None:
        if self._worker is None or not self._worker.is_alive():
            self._stop = False
            self._worker = threading.Thread(
                target=self._run, name="shell-scheduler", daemon=True)
            self._worker.start()

    def _run(self) -> None:
        while True:
            with self._lock:
                while (not self._stop
                       and (self._paused
                            or (not self._intake and not self._has_ready()))):
                    self._work_cv.wait(timeout=0.25)
                if self._stop:
                    return
                intake = list(self._intake)
                self._intake.clear()
            self._ingest(intake)
            # issue credit-gated batches, drive the arbiter, repeat: every
            # completed batch returns credits that may unblock more work.
            while True:
                issued = self._issue_ready()
                self.arbiter.drain()
                if not issued and not self.arbiter.pending():
                    with self._lock:
                        if self._intake or self._paused or self._stop:
                            break
                        if not self._has_ready():
                            self._idle_cv.notify_all()
                            break
                    # ready work exists but was credit-blocked with an idle
                    # arbiter: impossible by construction (credits release
                    # inside arbiter.drain()), but never spin.
                    time.sleep(0.001)

    def _has_ready(self) -> bool:
        return any(self._pend.get(k) for k in self._pend_order)

    def _ingest(self, subs: List[_Submission]) -> None:
        for sub in subs:
            key = (sub.slot, sub.stream)
            if key not in self._pend:
                self._pend[key] = deque()
                self._pend_order.append(key)
            self._pend[key].append(sub)

    # ---------------------------------------------------------- batching ---
    def _form_batch(self, q: Deque[_Submission]) -> _Batch:
        """Pop a FIFO prefix of the stream queue: either one large entry or
        several small ones coalesced up to one packet / max_batch_entries.
        FIFO pop + single-requester submit = no same-stream reordering."""
        head = q.popleft()
        subs = [head]
        nbytes = head.nbytes
        if self.coalesce:
            while (q and len(subs) < self.max_batch_entries
                   and nbytes + q[0].nbytes <= self.packet_bytes):
                nxt = q.popleft()
                subs.append(nxt)
                nbytes += nxt.nbytes
        tenant = head.tenant
        requester = f"{tenant.name}/vfpga{head.slot}.s{head.stream}"
        npkts = max(len(C.packetize(nbytes, self.packet_bytes)), 1)
        return _Batch(tenant=tenant, requester=requester, subs=subs,
                      nbytes=nbytes, npkts=npkts)

    def _issue_ready(self) -> int:
        """Form batches from every stream queue head whose tenant has
        credits; submit them to the weighted arbiter.  Credit-blocked
        streams stay queued (head-of-line within the stream only)."""
        issued = 0
        for key in list(self._pend_order):
            q = self._pend.get(key)
            while q:
                head = q[0]
                ten = head.tenant
                # probe the credit cost of the batch the head would form
                # without popping: cost is bounded by capacity (a single
                # over-sized transfer may otherwise deadlock).
                probe_pkts = max(
                    len(C.packetize(head.nbytes, self.packet_bytes)), 1)
                cost = min(probe_pkts, ten.credits.capacity)
                if not ten.credits.try_acquire(cost):
                    break                      # tenant back-pressured
                batch = self._form_batch(q)
                # coalescing never changes the packet count (it only fills
                # up to ONE packet, and over-packet heads ride alone), so
                # the probed cost is the batch cost.
                assert min(batch.npkts, ten.credits.capacity) == cost
                self._submit_batch(batch, credit_cost=cost)
                issued += 1
        return issued

    def _submit_batch(self, batch: _Batch, *, credit_cost: int) -> None:
        tenant = batch.tenant
        with self._lock:
            reqs = self._tenant_requesters.setdefault(tenant.name, set())
            reqs.add(batch.requester)
            # rebalance over the currently-backlogged requesters (plus this
            # one, about to be backlogged) so drained streams stop diluting
            # the tenant's share.
            self._rebalance_weights(tenant.name, extra=batch.requester)
        self.batches_issued += 1
        if len(batch.subs) > 1:
            self.entries_coalesced += len(batch.subs)
        tag = batch.subs[0].sg.opcode.value if batch.subs[0].ticket >= 0 \
            else batch.subs[0].sg.meta.get("tag", "io")

        def done(_t, batch=batch, credit_cost=credit_cost):
            self._complete_batch(batch, credit_cost)

        self.arbiter.submit(batch.requester, batch.nbytes, tag=tag,
                            on_done=done)

    def _complete_batch(self, batch: _Batch, credit_cost: int) -> None:
        """Runs on the scheduler thread when the batch's last packet clears
        the link: execute each SG in submission order, complete CQs,
        release credits, update tenant QoS counters."""
        now = time.perf_counter()
        ten = batch.tenant
        for sub in batch.subs:
            if sub.execute is not None:
                comp = sub.execute(sub.ticket, sub.sg)
                if sub.complete is not None:
                    sub.complete(comp)
            if sub.done_event is not None:
                sub.done_event.set()
            if sub.on_done is not None:
                try:
                    sub.on_done()
                except Exception:   # noqa: BLE001 — a bad callback must
                    pass            # never kill the scheduler thread
            ten.completions += 1
            ten.lat_sum_s += now - sub.t_submit
        ten.batches += 1
        ten.bytes_done += batch.nbytes
        ten.t_last_done = now
        ten.credits.release(credit_cost)
        with self._lock:
            ten.pending -= len(batch.subs)
            self._inflight -= len(batch.subs)
            # wakes both drain() waiters and back-pressured submitters
            self._idle_cv.notify_all()

    # --------------------------------------------------------------- QoS ---
    def stats(self) -> Dict[str, Any]:
        with self._lock:
            tenants = dict(self._tenants)
        total = sum(t.bytes_done for t in tenants.values()) or 1
        shares = {n: t.bytes_done / total for n, t in tenants.items()}
        weights = {n: t.weight for n, t in tenants.items()}
        per_tenant = {}
        for n, t in tenants.items():
            s = t.stats()
            s["share"] = shares[n]
            per_tenant[n] = s
        return {
            "tenants": per_tenant,
            "jain_tenant": C.jains_index(shares),
            "jain_weighted": C.weighted_jains_index(shares, weights),
            "total_bytes": sum(t.bytes_done for t in tenants.values()),
            "batches": self.batches_issued,
            "entries_coalesced": self.entries_coalesced,
        }
