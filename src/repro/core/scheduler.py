"""Multi-tenant shell scheduler: async submission + weighted-credit QoS.

Replaces the synchronous per-slot ``Shell.kick()`` drain loop with an
event-driven subsystem in front of the link arbiter:

  * **Async intake** — cThreads on any vFPGA slot enqueue scatter-gather
    work concurrently; a single scheduler thread (the "shell datapath
    clock") ingests, batches, and issues it.  Callers synchronize on the
    completion queues exactly as before.
  * **Coalescing** — consecutive small SG entries on the same
    (slot, stream) are merged into one packet-sized batch before hitting
    the arbiter, so tiny descriptors stop costing a full arbiter visit
    each.  Batches never span streams and never reorder entries: each
    (slot, stream) is a FIFO end to end.
  * **Weighted credits** — every tenant owns a credit account sized by its
    weight; batches acquire one credit per packet before entering the
    arbiter and release on completion, so an over-subscribed tenant stalls
    itself, never the link (back-pressure containment, paper §7.2).
  * **Weighted bandwidth** — the :class:`~repro.core.credits.WeightedRRArbiter`
    serves each (slot, stream) queue with its tenant's weight, split evenly
    across the tenant's active queues so a tenant's share is set by its
    weight, not its stream count.
  * **Per-tenant QoS stats** — byte shares, weighted/unweighted Jain's
    fairness, mean submit→complete latency, and throughput, surfaced
    through ``Shell.status()["scheduler"]``.
  * **Per-slot executor lanes** — the DWRR arbiter keeps deciding *what*
    is granted (billing and fairness are unchanged), but granted work is
    *executed* on per-slot worker lanes: one lane per vFPGA slot that has
    traffic, plus one shared lane for service-port calls.  A long-running
    app invocation on slot 0 (an lm_serving serve loop, a streaming NN
    predict) therefore no longer delays slot 1's completions — execution
    is parallel across slots while each (slot, stream) stays FIFO.
  * **Cooperative preemption** — submissions carry ``priority`` and an
    absolute ``deadline``; a lane runs the highest-priority stream-head
    first (earliest deadline breaks ties), and a long-running invocation
    that calls :meth:`ShellScheduler.checkpoint` at its natural
    boundaries (decode step, stream batch) *holds* while queued
    strictly-higher-priority work on its lane runs, then *resumes* — the
    in-flight batch is preempted without ever being lost or duplicated
    (the same hold-and-resume contract as the Port drain machinery).
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional, Set, Tuple

from repro.core import credits as C
from repro.core.faults import FaultKind
from repro.core.interfaces import Completion, SgEntry

DEFAULT_TENANT_PREFIX = "tenant"

# Slots at or above this id are synthetic (service ports, see
# ``repro.core.port.SERVICE_SLOT_BASE``): they share ONE executor lane —
# service calls are short control operations, not long-running datapath
# work, so a shared lane keeps thread count bounded.
SHARED_LANE_SLOT_BASE = 1000
SHARED_LANE_KEY = "service"


@dataclass
class Tenant:
    """One bandwidth principal: a weight, a credit account, QoS counters."""
    name: str
    weight: float = 1.0
    credits: C.CreditAccount = None          # set by the scheduler
    submissions: int = 0
    completions: int = 0
    pending: int = 0                         # accepted, not yet completed
    intake_stalls: int = 0                   # submitter back-pressure events
    batches: int = 0
    bytes_done: int = 0
    lat_sum_s: float = 0.0
    deadline_misses: int = 0                 # completed past their deadline
    t_first_submit: float = 0.0
    t_last_done: float = 0.0

    def stats(self) -> Dict[str, float]:
        span = max(self.t_last_done - self.t_first_submit, 1e-12)
        return {
            "weight": self.weight,
            "submissions": self.submissions,
            "completions": self.completions,
            "batches": self.batches,
            "bytes": self.bytes_done,
            "deadline_misses": self.deadline_misses,
            "mean_latency_s": self.lat_sum_s / max(self.completions, 1),
            "throughput_bps": self.bytes_done / span if self.bytes_done
            else 0.0,
            "credit_capacity": self.credits.capacity if self.credits else 0,
            "credit_stalls": self.credits.stalls if self.credits else 0,
            "intake_stalls": self.intake_stalls,
        }


@dataclass
class _Submission:
    slot: int
    stream: int
    ticket: int
    sg: SgEntry
    tenant: Tenant
    nbytes: int
    t_submit: float
    execute: Optional[Callable[[int, SgEntry], Completion]] = None
    complete: Optional[Callable[[Completion], None]] = None
    done_event: Optional[threading.Event] = None
    on_done: Optional[Callable[[], None]] = None
    priority: int = 0
    deadline: float = float("inf")           # absolute perf_counter time


@dataclass
class _Batch:
    tenant: Tenant
    requester: str
    subs: List[_Submission]
    nbytes: int
    npkts: int
    priority: int = 0
    deadline: float = float("inf")


@dataclass
class _ExecTask:
    """One granted batch awaiting execution on a lane."""
    batch: _Batch
    credit_cost: int
    seq: int

    @property
    def priority(self) -> int:
        return self.batch.priority

    @property
    def stream_key(self) -> Tuple[int, int]:
        head = self.batch.subs[0]
        return (head.slot, head.stream)

    def order_key(self) -> Tuple[float, float, int]:
        return (-self.batch.priority, self.batch.deadline, self.seq)


class _ExecutorLane:
    """One execution lane: a worker thread draining granted batches for
    one vFPGA slot (or the shared service lane).

    Scheduling inside a lane is priority-first (earliest deadline, then
    grant order, break ties) over *stream heads*: a task is only eligible
    while no earlier-granted task of the same (slot, stream) is still
    queued, so the scheduler's per-stream FIFO guarantee survives
    reordering across priorities."""

    def __init__(self, key: Any, scheduler: "ShellScheduler"):
        self.key = key
        self.sched = scheduler
        self._cv = threading.Condition()
        self._queue: List[_ExecTask] = []
        self._stop = False
        self.current: Optional[_ExecTask] = None
        # tasks held at checkpoints on this thread, outermost first; a
        # preemptor must never share a stream with any of them (its
        # same-stream predecessor is in flight, just not in _queue)
        self._hold_chain: List[_ExecTask] = []
        self.executed = 0
        self.preempt_runs = 0            # tasks run inside a checkpoint hold
        self.queue_peak = 0
        self.busy_s = 0.0
        self.thread = threading.Thread(
            target=self._run, name=f"shell-lane-{key}", daemon=True)
        self.thread.start()

    # ------------------------------------------------------------ intake ---
    def push(self, task: _ExecTask) -> None:
        with self._cv:
            self._queue.append(task)
            self.queue_peak = max(self.queue_peak, len(self._queue))
            self._cv.notify_all()

    def _pop_locked(self, above_priority: Optional[int] = None,
                    exclude_streams: Optional[Set[Tuple[int, int]]] = None
                    ) -> Optional[_ExecTask]:
        """Best eligible task: for each (slot, stream) only the earliest
        queued task is a candidate (FIFO within a stream); among the
        candidates the highest priority wins, then the earliest deadline,
        then grant order.  ``above_priority`` restricts candidates to
        strictly higher priorities and ``exclude_streams`` blocks streams
        whose earlier batch is in flight on this thread (both together
        form the preemption filter: priority reorders only ACROSS
        streams, never within one)."""
        best = None
        seen: Set[Tuple[int, int]] = set(exclude_streams or ())
        for i, t in enumerate(self._queue):
            sk = t.stream_key
            if sk in seen:
                continue
            seen.add(sk)
            if above_priority is not None and t.priority <= above_priority:
                continue
            if best is None or t.order_key() < self._queue[best].order_key():
                best = i
        if best is None:
            return None
        return self._queue.pop(best)

    # ------------------------------------------------------------ worker ---
    def _run(self) -> None:
        while True:
            with self._cv:
                while not self._queue and not self._stop:
                    self._cv.wait(timeout=0.25)
                if self._stop and not self._queue:
                    return
                task = self._pop_locked()
            if task is not None:
                self._execute(task)

    def _execute(self, task: _ExecTask) -> None:
        prev = self.current
        with self._cv:                  # _hold_chain/current are read by
            self.current = task         # cross-thread probes under _cv
            self._hold_chain.append(task)
        t0 = time.perf_counter()
        try:
            self.sched._execute_batch(task.batch, task.credit_cost)
        finally:
            self.busy_s += time.perf_counter() - t0
            self.executed += 1
            with self._cv:
                self._hold_chain.pop()
                self.current = prev

    # -------------------------------------------------------- preemption ---
    def run_preemptors(self) -> int:
        """Checkpoint body: while queued work outranks the in-flight task,
        run it inline (the in-flight batch HOLDS here and RESUMES after).
        Work sharing a (slot, stream) with any held batch is never
        eligible — its same-stream predecessor is mid-flight, and
        per-stream FIFO is inviolable.  Only meaningful on the lane's
        own thread."""
        cur = self.current
        if cur is None:
            return 0
        ran = 0
        while True:
            with self._cv:
                held = {t.stream_key for t in self._hold_chain}
                task = self._pop_locked(above_priority=cur.priority,
                                        exclude_streams=held)
            if task is None:
                return ran
            self.preempt_runs += 1
            ran += 1
            self._execute(task)

    def pending_above(self, priority: int) -> bool:
        with self._cv:
            held = {t.stream_key for t in self._hold_chain}
            return any(t.priority > priority
                       and t.stream_key not in held for t in self._queue)

    def preempt_pending(self) -> bool:
        """Coherent probe: is queued work outranking the in-flight task
        (one lock, so current and queue are read consistently)?"""
        with self._cv:
            cur = self.current
            if cur is None:
                return False
            held = {t.stream_key for t in self._hold_chain}
            return any(t.priority > cur.priority
                       and t.stream_key not in held for t in self._queue)

    # ----------------------------------------------------------- teardown --
    def close(self, timeout: float = 2.0) -> None:
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        self.thread.join(timeout=timeout)

    def stats(self) -> Dict[str, Any]:
        with self._cv:
            qlen = len(self._queue)
            cur = self.current
        return {"executed": self.executed, "queued": qlen,
                "queue_peak": self.queue_peak,
                "preempt_runs": self.preempt_runs,
                "busy_s": self.busy_s,
                "current_priority": (cur.priority if cur is not None
                                     else None)}


class ShellScheduler:
    """Event-driven multi-tenant scheduler in front of a weighted arbiter."""

    def __init__(self, arbiter: C.WeightedRRArbiter, *,
                 packet_bytes: int = C.DEFAULT_PACKET_BYTES,
                 stream_depth: int = 64,
                 coalesce: bool = True,
                 max_batch_entries: int = 16,
                 max_pending_per_tenant: Optional[int] = None,
                 lanes: bool = True):
        self.arbiter = arbiter
        # lanes=False serializes every execution on the scheduler worker
        # (the pre-lane behavior) — kept as the A/B baseline for
        # ``benchmarks/bench_multislot.py`` and the billing-parity tests.
        self.lanes_enabled = lanes
        self._lanes: Dict[Any, _ExecutorLane] = {}
        self._lane_threads: Set[threading.Thread] = set()
        self._exec_seq = itertools.count()
        self.packet_bytes = packet_bytes
        self.stream_depth = stream_depth
        self.coalesce = coalesce
        self.max_batch_entries = max_batch_entries
        # submitter-side back-pressure bound (paper §7.2: an over-subscribed
        # tenant stalls ITSELF): submissions beyond this block the caller
        # until completions free room.  pause() exempts itself — it exists
        # precisely to build up saturation backlogs deterministically.
        self.max_pending_per_tenant = (max_pending_per_tenant
                                       if max_pending_per_tenant is not None
                                       else 64 * stream_depth)

        self._tenants: Dict[str, Tenant] = {}
        self._slot_tenant: Dict[int, str] = {}
        # requester name -> tenant, for weight rebalancing across a
        # tenant's active (slot, stream) queues
        self._tenant_requesters: Dict[str, Set[str]] = {}

        self._intake: Deque[_Submission] = deque()
        self._pend: Dict[Tuple[int, int], Deque[_Submission]] = {}
        self._pend_order: List[Tuple[int, int]] = []

        self._lock = threading.Lock()
        self._work_cv = threading.Condition(self._lock)
        self._idle_cv = threading.Condition(self._lock)
        self._inflight = 0
        self._paused = False
        self._stop = False
        self._worker: Optional[threading.Thread] = None

        self.batches_issued = 0
        self.entries_coalesced = 0          # entries that rode in a batch >1
        # robustness wiring (set by Shell.set_fault_plan / Shell.__init__):
        # an armed FaultPlan probed at "lane.execute"/"io.complete", and the
        # HealthMonitor that lane heartbeats + fault records feed.
        self.faults: Optional[Any] = None
        self.health: Optional[Any] = None
        self.lane_faults = 0                # execute/io bodies that raised

    # ------------------------------------------------------------ tenants --
    def register_tenant(self, name: str, weight: float = 1.0) -> Tenant:
        """Create/update a tenant.  Credit capacity scales with weight so a
        heavier tenant may keep proportionally more packets in flight."""
        if weight <= 0:
            raise ValueError(f"tenant weight must be > 0, got {weight}")
        with self._lock:
            t = self._tenants.get(name)
            if t is None:
                t = Tenant(name=name, weight=weight)
                t.credits = C.CreditAccount(
                    max(1, int(round(self.stream_depth * weight))),
                    on_release=self._credits_released)
                self._tenants[name] = t
                self._tenant_requesters.setdefault(name, set())
            elif t.weight != weight:
                t.weight = weight
                t.credits = C.CreditAccount(
                    max(1, int(round(self.stream_depth * weight))),
                    on_release=self._credits_released)
                self._rebalance_weights(name)
        return t

    def _credits_released(self) -> None:
        """Lane threads release credits asynchronously now; wake the
        issue loop so credit-blocked streams are revisited promptly."""
        with self._lock:
            self._work_cv.notify_all()

    def bind_slot(self, slot: int, tenant: str) -> None:
        """Route all submissions from a vFPGA slot to the named tenant."""
        if tenant not in self._tenants:
            self.register_tenant(tenant)
        with self._lock:
            self._slot_tenant[slot] = tenant

    def tenant_of(self, slot: int) -> Tenant:
        with self._lock:
            name = self._slot_tenant.get(slot)
        if name is None:
            name = f"{DEFAULT_TENANT_PREFIX}{slot}"
            self._tenant_by_name(name)
            self.bind_slot(slot, name)
        return self._tenants[name]

    def _tenant_by_name(self, name: str) -> Tenant:
        """Get-or-create WITHOUT touching an existing tenant's weight
        (register_tenant with the default weight would reset it)."""
        with self._lock:
            t = self._tenants.get(name)
        if t is not None:
            return t
        return self.register_tenant(name)

    def tenants(self) -> Dict[str, Tenant]:
        with self._lock:
            return dict(self._tenants)

    def tenant_pending(self, name: str) -> int:
        """Accepted-but-uncompleted submissions for a tenant — surfaced
        by ``ServingEngine.run()`` stats (``io_pending``) so async
        decode-IO billing that failed to drain is visible, never silent."""
        with self._lock:
            t = self._tenants.get(name)
            return t.pending if t is not None else 0

    def _rebalance_weights(self, tenant_name: str,
                           extra: Optional[str] = None) -> None:
        """Split a tenant's weight evenly over its BACKLOGGED requesters so
        its link share tracks its weight regardless of how many
        (slot, stream) queues it currently fans out on.  Requesters whose
        arbiter queue has drained stop diluting the share (they are
        re-included by the rebalance accompanying their next batch).
        Caller must hold self._lock."""
        t = self._tenants[tenant_name]
        reqs = self._tenant_requesters.get(tenant_name, set())
        active = {r for r in reqs if self.arbiter.backlogged(r)}
        if extra is not None:
            active.add(extra)
        if not active:
            return
        per = t.weight / len(active)
        for r in active:
            self.arbiter.set_weight(r, per)

    # ------------------------------------------------------------- intake --
    def submit(self, *, slot: int, stream: int, ticket: int, sg: SgEntry,
               execute: Callable[[int, SgEntry], Completion],
               complete: Callable[[Completion], None],
               tenant: Optional[str] = None,
               priority: int = 0,
               deadline_s: Optional[float] = None) -> None:
        """Enqueue one SG descriptor (any thread; blocks only when the
        tenant exceeds its pending bound — submitter-side back-pressure).
        ``priority`` orders execution on the slot's lane (higher first;
        the DWRR grant order and billing are unaffected); ``deadline_s``
        is a relative SLO in seconds breaking ties among equal
        priorities (earliest absolute deadline first)."""
        ten = (self._tenant_by_name(tenant) if tenant is not None
               else self.tenant_of(slot))
        t_sub = time.perf_counter()
        sub = _Submission(slot=slot, stream=stream, ticket=ticket, sg=sg,
                          tenant=ten, nbytes=max(sg.length, 1),
                          t_submit=t_sub,
                          execute=execute, complete=complete,
                          priority=priority,
                          deadline=(t_sub + deadline_s
                                    if deadline_s is not None
                                    else float("inf")))
        self._enqueue(sub)

    def submit_io(self, nbytes: int, *, slot: int = 0, stream: int = 0,
                  tenant: Optional[str] = None, tag: str = "io",
                  wait: bool = False,
                  timeout: Optional[float] = None,
                  on_done: Optional[Callable[[], None]] = None,
                  priority: int = 0,
                  deadline_s: Optional[float] = None
                  ) -> threading.Event:
        """Enqueue a raw transfer with no SG execution behind it — the path
        the serving engine uses to push its decode-step I/O through the
        shared link under this tenant's QoS weight.  ``on_done`` (used by
        the Port layer to resolve futures) fires once the bytes clear the
        link, on whichever thread completed them."""
        ten = (self._tenant_by_name(tenant) if tenant is not None
               else self.tenant_of(slot))
        if self._on_executor_thread():
            # Re-entrant submission from inside an executing batch (e.g. a
            # serving app's decode loop running under execute_sg, on the
            # scheduler worker or on a lane): waiting on our own thread
            # would deadlock, so bill the link and the tenant inline.
            # Bytes still land in the arbiter's delivered table so tenant
            # totals and arbiter totals stay reconciled.  Lanes-on and
            # lanes-off take the same path here, so billed totals are
            # identical in both modes.
            if self.faults is not None:
                # same injection site as the queued path; raises BEFORE
                # any accounting mutates, so the caller's typed-failure
                # path (Port._safe_dispatch) sees a clean state
                self.faults.fire("io.complete", slot=slot,
                                 tenant=ten.name, tag=tag)
            t_sub = time.perf_counter()
            requester = f"{ten.name}/vfpga{slot}.s{stream}:inline"
            with self._lock:
                if ten.t_first_submit == 0.0:
                    ten.t_first_submit = t_sub
                ten.submissions += 1
            self.arbiter.link.transfer(max(nbytes, 1), src=requester,
                                       tag=tag)
            now = time.perf_counter()
            with self._lock:
                self.arbiter.delivered[requester] = (
                    self.arbiter.delivered.get(requester, 0)
                    + max(nbytes, 1))
                ten.completions += 1
                ten.bytes_done += max(nbytes, 1)
                ten.lat_sum_s += now - t_sub
                ten.t_last_done = now
            ev = threading.Event()
            ev.set()
            if on_done is not None:
                on_done()
            return ev
        t_sub = time.perf_counter()
        sg = SgEntry(length=max(nbytes, 1), src_stream=stream,
                     meta={"tag": tag})
        sub = _Submission(slot=slot, stream=stream, ticket=-1, sg=sg,
                          tenant=ten, nbytes=max(nbytes, 1),
                          t_submit=t_sub,
                          done_event=threading.Event(), on_done=on_done,
                          priority=priority,
                          deadline=(t_sub + deadline_s
                                    if deadline_s is not None
                                    else float("inf")))
        self._enqueue(sub)
        if wait:
            sub.done_event.wait(timeout=timeout)
        return sub.done_event

    def _on_executor_thread(self) -> bool:
        """True on the scheduler worker or any executor lane thread —
        the threads that drain work and must never block on themselves."""
        cur = threading.current_thread()
        if self._worker is not None and cur is self._worker:
            return True
        return cur in self._lane_threads

    def _enqueue(self, sub: _Submission) -> None:
        on_worker = self._on_executor_thread()
        with self._lock:
            # submitter-side back-pressure: an over-subscribed tenant
            # stalls itself, never the link or other tenants.  Skipped
            # while paused (pause() exists to build saturation backlogs)
            # and on the worker thread (it is the one draining).
            while (not self._paused and not on_worker
                   and sub.tenant.pending >= self.max_pending_per_tenant):
                sub.tenant.intake_stalls += 1
                self._idle_cv.wait(timeout=0.25)
            if sub.tenant.t_first_submit == 0.0:
                sub.tenant.t_first_submit = sub.t_submit
            sub.tenant.submissions += 1
            sub.tenant.pending += 1
            self._inflight += 1
            self._intake.append(sub)
            self._ensure_worker_locked()
            self._work_cv.notify_all()

    # ------------------------------------------------------- flow control --
    def pause(self) -> None:
        """Hold scheduling (submissions still accepted).  Lets callers build
        up saturation demand before any byte moves — deterministic QoS
        benchmarks depend on this."""
        with self._lock:
            self._paused = True

    def resume(self) -> None:
        with self._lock:
            self._paused = False
            self._work_cv.notify_all()

    def drain_tenant(self, name: str, timeout: Optional[float] = None
                     ) -> bool:
        """Tenant-aware drain: block until the NAMED tenant's accepted
        submissions have all completed, while every other tenant keeps
        flowing (nothing is paused and no other queue is touched).

        This is the drain-ordering primitive quiesce-and-migrate builds
        on: the migrating tenant's in-flight tail is waited out first,
        bystander tenants on the same shell never see a stall.  Returns
        True once the tenant is idle (an unknown tenant is trivially
        idle), False on timeout.
        """
        deadline = (None if timeout is None
                    else time.perf_counter() + timeout)
        with self._lock:
            t = self._tenants.get(name)
            if t is None:
                return True
            self._ensure_worker_locked()
            self._work_cv.notify_all()
            while t.pending > 0:
                remaining = (None if deadline is None
                             else deadline - time.perf_counter())
                if remaining is not None and remaining <= 0:
                    return False
                self._idle_cv.wait(timeout=remaining if remaining else 0.25)
            return True

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until every accepted submission has completed."""
        deadline = (None if timeout is None
                    else time.perf_counter() + timeout)
        with self._lock:
            if self._paused:
                self._paused = False
            self._ensure_worker_locked()
            self._work_cv.notify_all()
            while self._inflight > 0:
                remaining = (None if deadline is None
                             else deadline - time.perf_counter())
                if remaining is not None and remaining <= 0:
                    return False
                self._idle_cv.wait(timeout=remaining if remaining else 0.25)
            return True

    def close(self) -> None:
        with self._lock:
            self._stop = True
            self._work_cv.notify_all()
        if self._worker is not None:
            self._worker.join(timeout=2.0)
        for lane in list(self._lanes.values()):
            lane.close()

    # ------------------------------------------------------------- worker --
    def _ensure_worker_locked(self) -> None:
        if self._worker is None or not self._worker.is_alive():
            self._stop = False
            self._worker = threading.Thread(
                target=self._run, name="shell-scheduler", daemon=True)
            self._worker.start()

    def _run(self) -> None:
        while True:
            with self._lock:
                while (not self._stop
                       and (self._paused
                            or (not self._intake and not self._has_ready()))):
                    self._work_cv.wait(timeout=0.25)
                if self._stop:
                    return
                intake = list(self._intake)
                self._intake.clear()
            self._ingest(intake)
            # issue credit-gated batches, drive the arbiter, repeat: every
            # completed batch returns credits that may unblock more work.
            while True:
                issued = self._issue_ready()
                self.arbiter.drain()
                if not issued and not self.arbiter.pending():
                    with self._lock:
                        if self._intake or self._paused or self._stop:
                            break
                        if not self._has_ready():
                            self._idle_cv.notify_all()
                            break
                        # ready work exists but is credit-blocked with an
                        # idle arbiter: credits are held by batches still
                        # executing on lanes.  Wait for a release
                        # (CreditAccount.on_release notifies _work_cv).
                        self._work_cv.wait(timeout=0.05)

    def _has_ready(self) -> bool:
        return any(self._pend.get(k) for k in self._pend_order)

    def _ingest(self, subs: List[_Submission]) -> None:
        for sub in subs:
            key = (sub.slot, sub.stream)
            if key not in self._pend:
                self._pend[key] = deque()
                self._pend_order.append(key)
            self._pend[key].append(sub)

    # ---------------------------------------------------------- batching ---
    def _form_batch(self, q: Deque[_Submission]) -> _Batch:
        """Pop a FIFO prefix of the stream queue: either one large entry or
        several small ones coalesced up to one packet / max_batch_entries.
        FIFO pop + single-requester submit = no same-stream reordering.
        Coalescing never crosses a priority boundary — a batch has ONE
        priority, so lane-level preemption can never invert priorities
        inside a merged batch."""
        head = q.popleft()
        subs = [head]
        nbytes = head.nbytes
        deadline = head.deadline
        if self.coalesce:
            while (q and len(subs) < self.max_batch_entries
                   and q[0].priority == head.priority
                   and nbytes + q[0].nbytes <= self.packet_bytes):
                nxt = q.popleft()
                subs.append(nxt)
                nbytes += nxt.nbytes
                deadline = min(deadline, nxt.deadline)
        tenant = head.tenant
        requester = f"{tenant.name}/vfpga{head.slot}.s{head.stream}"
        npkts = max(len(C.packetize(nbytes, self.packet_bytes)), 1)
        return _Batch(tenant=tenant, requester=requester, subs=subs,
                      nbytes=nbytes, npkts=npkts, priority=head.priority,
                      deadline=deadline)

    def _issue_ready(self) -> int:
        """Form batches from every stream queue head whose tenant has
        credits; submit them to the weighted arbiter.  Credit-blocked
        streams stay queued (head-of-line within the stream only)."""
        issued = 0
        for key in list(self._pend_order):
            q = self._pend.get(key)
            while q:
                head = q[0]
                ten = head.tenant
                # probe the credit cost of the batch the head would form
                # without popping: cost is bounded by capacity (a single
                # over-sized transfer may otherwise deadlock).
                probe_pkts = max(
                    len(C.packetize(head.nbytes, self.packet_bytes)), 1)
                cost = min(probe_pkts, ten.credits.capacity)
                if not ten.credits.try_acquire(cost):
                    break                      # tenant back-pressured
                batch = self._form_batch(q)
                # coalescing never changes the packet count (it only fills
                # up to ONE packet, and over-packet heads ride alone), so
                # the probed cost is the batch cost.
                assert min(batch.npkts, ten.credits.capacity) == cost
                self._submit_batch(batch, credit_cost=cost)
                issued += 1
        return issued

    def _submit_batch(self, batch: _Batch, *, credit_cost: int) -> None:
        tenant = batch.tenant
        with self._lock:
            reqs = self._tenant_requesters.setdefault(tenant.name, set())
            reqs.add(batch.requester)
            # rebalance over the currently-backlogged requesters (plus this
            # one, about to be backlogged) so drained streams stop diluting
            # the tenant's share.
            self._rebalance_weights(tenant.name, extra=batch.requester)
        self.batches_issued += 1
        if len(batch.subs) > 1:
            self.entries_coalesced += len(batch.subs)
        tag = batch.subs[0].sg.opcode.value if batch.subs[0].ticket >= 0 \
            else batch.subs[0].sg.meta.get("tag", "io")

        def done(_t, batch=batch, credit_cost=credit_cost):
            self._complete_batch(batch, credit_cost)

        self.arbiter.submit(batch.requester, batch.nbytes, tag=tag,
                            on_done=done)

    def _complete_batch(self, batch: _Batch, credit_cost: int) -> None:
        """Runs on the scheduler thread when the batch's last packet
        clears the link.  The grant is done — now route *execution*:
        batches carrying SG work go to their slot's executor lane (so a
        long invocation on one slot never delays another slot's
        completions); pure-I/O batches (no execute callable — the
        serving engine's decode billing) finish inline, so their futures
        resolve even while every lane is busy with long work.

        Consequence, by design: a pure-I/O completion is a link
        accounting record and is NOT ordered relative to SG *execution*
        on the same (slot, stream) — the per-stream FIFO contract covers
        SG execution order; an I/O future must never be used as a
        barrier for earlier SG work (a batch that mixes both kinds rides
        the lane as one unit and stays internally ordered)."""
        if self.lanes_enabled and any(s.execute is not None
                                      for s in batch.subs):
            self._lane_for(batch.subs[0].slot).push(_ExecTask(
                batch=batch, credit_cost=credit_cost,
                seq=next(self._exec_seq)))
            return
        self._execute_batch(batch, credit_cost)

    def _execute_batch(self, batch: _Batch, credit_cost: int) -> None:
        """Execute each SG in submission order, complete CQs, release
        credits, update tenant QoS counters.  Runs on a lane thread
        (lanes on) or the scheduler worker (lanes off / pure I/O).

        Failure-hardened: an exception out of an execute body (app bug or
        injected ``lane.execute``/``io.complete`` fault) is converted into
        a failed ``Completion`` (SG work) or an error callback (IO work)
        for THAT submission only — the rest of the batch still completes,
        and the ``finally`` block guarantees credits are released and
        tenant accounting settles even on the worst path, so a crash can
        never leak credits or wedge ``drain()`` waiters forever."""
        ten = batch.tenant
        plan = self.faults
        try:
            for sub in batch.subs:
                err: Optional[BaseException] = None
                comp: Optional[Completion] = None
                try:
                    if plan is not None:
                        plan.fire("lane.execute" if sub.execute is not None
                                  else "io.complete",
                                  slot=sub.slot, tenant=ten.name,
                                  ticket=sub.ticket)
                    if sub.execute is not None:
                        comp = sub.execute(sub.ticket, sub.sg)
                except BaseException as e:  # noqa: BLE001 — the lane
                    # must outlive anything the body throws
                    err = e
                    self.lane_faults += 1
                if err is not None and sub.execute is not None:
                    # the SG path already speaks failed Completions
                    # (service rejections, app exceptions): deliver the
                    # typed fault the same way so the Port layer's retry
                    # policy can intercept it in _finish
                    if self.health is not None:
                        self.health.record_fault(
                            getattr(err, "kind", FaultKind.LANE_CRASH),
                            slot=sub.slot, tenant=ten.name,
                            site=getattr(err, "site", "lane.execute"),
                            msg=str(err))
                    comp = Completion(
                        ticket=sub.ticket, tid=sub.sg.tid,
                        opcode=sub.sg.opcode, nbytes=sub.nbytes,
                        t_submit=sub.t_submit,
                        t_done=time.perf_counter(), ok=False, result=err)
                if sub.complete is not None and comp is not None:
                    try:
                        sub.complete(comp)
                    except Exception:  # noqa: BLE001 — a bad completion
                        pass           # callback must not kill the lane
                if sub.done_event is not None:
                    sub.done_event.set()
                if sub.on_done is not None:
                    try:
                        if (err is not None and getattr(
                                sub.on_done, "accepts_error", False)):
                            # Port-layer IO callback: the error fails the
                            # future typed (and is health-recorded there)
                            sub.on_done(err)
                        else:
                            sub.on_done()
                    except Exception:   # noqa: BLE001 — a bad callback
                        pass            # must never kill the thread
        finally:
            now = time.perf_counter()
            ten.credits.release(credit_cost)
            with self._lock:
                for sub in batch.subs:
                    ten.completions += 1
                    ten.lat_sum_s += now - sub.t_submit
                    if now > sub.deadline:
                        # SLO accounting: the invocation finished past
                        # its absolute deadline (inf = no deadline)
                        ten.deadline_misses += 1
                ten.batches += 1
                ten.bytes_done += batch.nbytes
                ten.t_last_done = now
                ten.pending -= len(batch.subs)
                self._inflight -= len(batch.subs)
                # wakes both drain() waiters and back-pressured submitters
                self._idle_cv.notify_all()
            if self.health is not None:
                # lane heartbeat: one beat per executed batch
                self.health.beat(batch.subs[0].slot)

    # -------------------------------------------------- executor lanes -----
    @staticmethod
    def _lane_key(slot: int) -> Any:
        return SHARED_LANE_KEY if slot >= SHARED_LANE_SLOT_BASE else slot

    def _lane_for(self, slot: int) -> _ExecutorLane:
        key = self._lane_key(slot)
        with self._lock:
            lane = self._lanes.get(key)
            if lane is None:
                lane = _ExecutorLane(key, self)
                self._lanes[key] = lane
                self._lane_threads.add(lane.thread)
        return lane

    def checkpoint(self, slot: int) -> int:
        """Cooperative preemption point for long-running invocations.

        Called from inside an executing invocation (decode-step /
        stream-batch granularity): if strictly-higher-priority granted
        work is queued on this slot's lane, it runs NOW on the calling
        thread — the caller's batch holds here and resumes when the
        call returns (zero lost, zero duplicated completions either
        side).  A no-op (returns 0) off the lane's own thread, with
        lanes disabled, or when nothing outranks the caller."""
        if not self.lanes_enabled:
            return 0
        # lock-free read: _lanes is append-only and this runs once per
        # decode step — taking the global scheduler lock here would
        # serialize every serving loop against the intake/issue path
        lane = self._lanes.get(self._lane_key(slot))
        if lane is None or threading.current_thread() is not lane.thread:
            return 0
        return lane.run_preemptors()

    def preempt_requested(self, slot: int) -> bool:
        """True when work outranking the slot's in-flight batch waits on
        its lane — the cheap probe form of :meth:`checkpoint`."""
        if not self.lanes_enabled:
            return False
        lane = self._lanes.get(self._lane_key(slot))   # append-only dict
        if lane is None:
            return False
        return lane.preempt_pending()

    # --------------------------------------------------------------- QoS ---
    def stats(self) -> Dict[str, Any]:
        with self._lock:
            tenants = dict(self._tenants)
        total = sum(t.bytes_done for t in tenants.values()) or 1
        shares = {n: t.bytes_done / total for n, t in tenants.items()}
        weights = {n: t.weight for n, t in tenants.items()}
        per_tenant = {}
        for n, t in tenants.items():
            s = t.stats()
            s["share"] = shares[n]
            per_tenant[n] = s
        with self._lock:
            lanes = {str(k): lane.stats() for k, lane in self._lanes.items()}
        return {
            "tenants": per_tenant,
            "jain_tenant": C.jains_index(shares),
            "jain_weighted": C.weighted_jains_index(shares, weights),
            "total_bytes": sum(t.bytes_done for t in tenants.values()),
            "batches": self.batches_issued,
            "entries_coalesced": self.entries_coalesced,
            "lane_faults": self.lane_faults,
            "lanes_enabled": self.lanes_enabled,
            "lanes": lanes,
        }
