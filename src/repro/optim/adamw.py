"""AdamW with global-norm clipping and cosine schedule (pure pytree ops).

Mixed-precision contract: params are stored fp32 (the "master" copy), the
model casts weights to the activation dtype at use sites, and the optimizer
moments are fp32 — 16 bytes/param of optimizer+param state, FSDP-sharded
with the same PartitionSpecs as the parameters.
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step):
    """Linear warmup -> cosine decay to min_lr_frac*lr."""
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr * (cfg.min_lr_frac
                    + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(math.pi * t)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params) -> Dict[str, Any]:
    zeros = lambda p: jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def state_specs(param_specs_tree) -> Dict[str, Any]:
    """Optimizer state shards exactly like the parameters."""
    from jax.sharding import PartitionSpec as P
    return {"m": param_specs_tree, "v": param_specs_tree, "step": P()}


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def update(grads, state, params, cfg: AdamWConfig, *,
           no_decay=lambda path: ("norm" in path or "bias" in path
                                  or path.endswith("scale"))):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = schedule(cfg, step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    flat_p = _flatten_with_path(params)
    flat_g = _flatten_with_path(grads)
    flat_m = _flatten_with_path(state["m"])
    flat_v = _flatten_with_path(state["v"])

    new_p, new_m, new_v = {}, {}, {}
    for path in flat_p:
        p = flat_p[path]
        g = flat_g[path].astype(jnp.float32) * scale
        m = cfg.b1 * flat_m[path] + (1 - cfg.b1) * g
        v = cfg.b2 * flat_v[path] + (1 - cfg.b2) * jnp.square(g)
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if not no_decay(path):
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p[path] = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        new_m[path] = m
        new_v[path] = v

    treedef = jax.tree.structure(params)
    unflat = lambda d: jax.tree.unflatten(treedef, [d[k] for k in flat_p])
    new_state = {"m": unflat(new_m), "v": unflat(new_v), "step": step}
    return unflat(new_p), new_state, {"grad_norm": gnorm, "lr": lr}


def _flatten_with_path(tree) -> Dict[str, jnp.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        out["/".join(_key_str(k) for k in path)] = leaf
    return out


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)
