"""Neural-network inference app (paper §9.7, Fig 12) + the hls4ml-style
Overlay API (Code 3: <10 lines of Python to deploy and predict).

Two datapaths are compared, mirroring the paper exactly:

  * **CoyoteAccelerator path** — weights pre-migrated to the card, inputs
    STREAMED host->vFPGA (async dispatch pipelines batch i+1's upload with
    batch i's compute), one AOT-compiled executable;
  * **staged-copy baseline (PYNQ/Vitis analogue)** — every batch is first
    copied host->card-HBM buffer, synchronized, then read back and fed to a
    separately dispatched compute call with per-call Python control.

The model is the line-rate network-intrusion-detection MLP the paper
deploys (unsw-nb15-ish: 593->64->64->1, quantized-friendly sizes).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.services.base import ServiceRequirement
from repro.core.vfpga import AppArtifact


@dataclass(frozen=True)
class MLPConfig:
    d_in: int = 593
    hidden: Tuple[int, ...] = (64, 64)
    d_out: int = 1


def init_mlp(rng, cfg: MLPConfig = MLPConfig()):
    dims = (cfg.d_in,) + cfg.hidden + (cfg.d_out,)
    keys = jax.random.split(rng, len(dims))
    params = []
    for i in range(len(dims) - 1):
        w = jax.random.normal(keys[i], (dims[i], dims[i + 1]),
                              jnp.float32) / np.sqrt(dims[i])
        params.append({"w": w, "b": jnp.zeros((dims[i + 1],), jnp.float32)})
    return params


def mlp_apply(params, x):
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            x = jax.nn.relu(x)
    return x


class CoyoteOverlay:
    """The <10-lines-of-Python deployment API (paper Code 3)."""

    def __init__(self, shell, slot: int = 0,
                 cfg: MLPConfig = MLPConfig(), seed: int = 0):
        self.shell = shell
        self.slot = slot
        self.cfg = cfg
        self.params = init_mlp(jax.random.PRNGKey(seed), cfg)
        self._compiled = None

    def program_fpga(self, *, warm_batch: int = 256) -> Dict[str, float]:
        """Load the NN as a vFPGA app (partial reconfiguration) and
        AOT-warm the executable for the serving batch size."""
        art = AppArtifact(
            name="nn_inference", fn=lambda iface, vf, x: self._predict_dev(x),
            weights=self.params,
            requires=[ServiceRequirement("mmu", {})],
            config_repr=self.cfg)
        stats = self.shell.load_app(self.slot, art)
        vf = self.shell.vfpgas[self.slot]
        self._compiled = jax.jit(mlp_apply)
        warm = jnp.zeros((warm_batch, self.cfg.d_in), jnp.float32)
        self._compiled(vf.device_weights, warm).block_until_ready()
        return stats

    def _predict_dev(self, x):
        vf = self.shell.vfpgas[self.slot]
        return self._compiled(vf.device_weights, x)

    def predict(self, X: np.ndarray, out_shape=(1,),
                batch_size: int = 256) -> np.ndarray:
        """Streamed inference: upload batch i+1 while batch i computes."""
        vf = self.shell.vfpgas[self.slot]
        n = X.shape[0]
        outs = []
        pending = None
        for i in range(0, n, batch_size):
            xb = jnp.asarray(X[i:i + batch_size])     # async H2D stream
            y = self._compiled(vf.device_weights, xb)  # async dispatch
            if pending is not None:
                outs.append(np.asarray(pending))       # sync previous
            pending = y
        if pending is not None:
            outs.append(np.asarray(pending))
        return np.concatenate(outs, axis=0)


class StagedCopyBaseline:
    """PYNQ/Vitis-style path: host -> HBM buffer (sync) -> kernel -> host,
    a fresh dispatch chain per batch with no overlap."""

    def __init__(self, params, cfg: MLPConfig = MLPConfig()):
        self.params = jax.device_put(params)
        self._stage = jax.jit(lambda x: x + 0)         # the HBM buffer copy
        self._fn = jax.jit(mlp_apply)

    def predict(self, X: np.ndarray, batch_size: int = 256) -> np.ndarray:
        outs = []
        for i in range(0, X.shape[0], batch_size):
            # pynq.allocate-style: fresh DMA buffer + host copy per call
            buf = np.empty_like(X[i:i + batch_size])
            buf[:] = X[i:i + batch_size]
            xb = jax.device_put(buf)                   # host -> card copy
            xb.block_until_ready()                     # staged: full sync
            staged = self._stage(xb)                   # card buffer write
            staged.block_until_ready()
            y = self._fn(self.params, staged)
            outs.append(np.asarray(y))                 # sync every batch
        return np.concatenate(outs, axis=0)
