"""Neural-network inference app (paper §9.7, Fig 12) + the hls4ml-style
Overlay API (Code 3: <10 lines of Python to deploy and predict).

Two datapaths are compared, mirroring the paper exactly:

  * **CoyoteAccelerator path** — weights pre-migrated to the card, inputs
    STREAMED host->vFPGA (async dispatch pipelines batch i+1's upload with
    batch i's compute), one AOT-compiled executable;
  * **staged-copy baseline (PYNQ/Vitis analogue)** — every batch is first
    copied host->card-HBM buffer, synchronized, then read back and fed to a
    separately dispatched compute call with per-call Python control.

The model is the line-rate network-intrusion-detection MLP the paper
deploys (unsw-nb15-ish: 593->64->64->1, quantized-friendly sizes).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.interfaces import Oper
from repro.core.port import Invocation, PortCapabilities
from repro.core.services.base import ServiceRequirement
from repro.core.vfpga import AppArtifact


CSR_NN_BATCH = 0x20               # serving batch size for the stream loop


@dataclass(frozen=True)
class MLPConfig:
    d_in: int = 593
    hidden: Tuple[int, ...] = (64, 64)
    d_out: int = 1


def init_mlp(rng, cfg: MLPConfig = MLPConfig()):
    dims = (cfg.d_in,) + cfg.hidden + (cfg.d_out,)
    keys = jax.random.split(rng, len(dims))
    params = []
    for i in range(len(dims) - 1):
        w = jax.random.normal(keys[i], (dims[i], dims[i + 1]),
                              jnp.float32) / np.sqrt(dims[i])
        params.append({"w": w, "b": jnp.zeros((dims[i + 1],), jnp.float32)})
    return params


def mlp_apply(params, x):
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            x = jax.nn.relu(x)
    return x


class CoyoteOverlay:
    """The <10-lines-of-Python deployment API (paper Code 3)."""

    def __init__(self, shell, slot: int = 0,
                 cfg: MLPConfig = MLPConfig(), seed: int = 0):
        self.shell = shell
        self.slot = slot
        self.cfg = cfg
        self.params = init_mlp(jax.random.PRNGKey(seed), cfg)
        self._compiled = None
        self._port = None

    def program_fpga(self, *, warm_batch: int = 256) -> Dict[str, float]:
        """Load the NN as a vFPGA app (partial reconfiguration) and
        AOT-warm the executable for the serving batch size."""
        art = make_nn_artifact(self)
        stats = self.shell.load_app(self.slot, art)
        vf = self.shell.vfpgas[self.slot]
        self._compiled = jax.jit(mlp_apply)
        warm = jnp.zeros((warm_batch, self.cfg.d_in), jnp.float32)
        self._compiled(vf.device_weights, warm).block_until_ready()
        self._port = self.shell.attach(self.slot)
        vf.iface.csr.set_csr(warm_batch, CSR_NN_BATCH)
        return stats

    def _predict_dev(self, x):
        vf = self.shell.vfpgas[self.slot]
        return self._compiled(vf.device_weights, x)

    def _predict_stream(self, iface, X: np.ndarray) -> np.ndarray:
        """The user logic's stream loop: upload batch i+1 while batch i
        computes (async dispatch), one sync per completed batch."""
        batch_size = max(iface.csr.get_csr(CSR_NN_BATCH, 256), 1)
        vf = self.shell.vfpgas[self.slot]
        outs = []
        pending = None
        for i in range(0, X.shape[0], batch_size):
            xb = jnp.asarray(X[i:i + batch_size])      # async H2D stream
            y = self._predict_dev(xb)                  # async dispatch
            if pending is not None:
                outs.append(np.asarray(pending))       # sync previous
            pending = y
            vf.checkpoint()        # stream-batch preemption checkpoint
        if pending is not None:
            outs.append(np.asarray(pending))
        return np.concatenate(outs, axis=0)

    def predict(self, X: np.ndarray, out_shape=(1,),
                batch_size: int = 256) -> np.ndarray:
        """One KERNEL invocation through the unified port per predict
        call; the pipelined stream loop runs inside the app logic (the
        batch size is a CSR, like any other slot control knob)."""
        from repro.core.interfaces import SgEntry
        vf = self.shell.vfpgas[self.slot]
        vf.iface.csr.set_csr(batch_size, CSR_NN_BATCH)
        comp = self._port.submit(Invocation.from_sg(SgEntry(
            src=X, length=int(X.nbytes),
            opcode=Oper.KERNEL))).result(timeout=120.0)
        if not comp.ok:
            raise comp.result
        return np.asarray(comp.result)


def make_nn_artifact(overlay: "CoyoteOverlay") -> AppArtifact:
    def fn(iface, vf, x):
        x = np.asarray(x)
        if x.ndim == 2:                     # full stream: pipelined loop
            return overlay._predict_stream(iface, x)
        return overlay._predict_dev(jnp.asarray(x))
    return AppArtifact(
        name="nn_inference", fn=fn,
        weights=overlay.params,
        requires=[ServiceRequirement("mmu", {})],
        config_repr=overlay.cfg,
        capabilities=PortCapabilities(
            name="nn_inference", kind="app", streams=1,
            csr_map={"batch_size": CSR_NN_BATCH},
            mem_model="device", ops=("kernel",)))


class StagedCopyBaseline:
    """PYNQ/Vitis-style path: host -> HBM buffer (sync) -> kernel -> host,
    a fresh dispatch chain per batch with no overlap."""

    def __init__(self, params, cfg: MLPConfig = MLPConfig()):
        self.params = jax.device_put(params)
        self._stage = jax.jit(lambda x: x + 0)         # the HBM buffer copy
        self._fn = jax.jit(mlp_apply)

    def predict(self, X: np.ndarray, batch_size: int = 256) -> np.ndarray:
        outs = []
        for i in range(0, X.shape[0], batch_size):
            # pynq.allocate-style: fresh DMA buffer + host copy per call
            buf = np.empty_like(X[i:i + batch_size])
            buf[:] = X[i:i + batch_size]
            xb = jax.device_put(buf)                   # host -> card copy
            xb.block_until_ready()                     # staged: full sync
            staged = self._stage(xb)                   # card buffer write
            staged.block_until_ready()
            y = self._fn(self.params, staged)
            outs.append(np.asarray(y))                 # sync every batch
        return np.concatenate(outs, axis=0)
