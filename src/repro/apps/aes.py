"""AES vFPGA apps: ECB (multi-tenant bench) and CBC (cThread bench).

Wraps the encryption service's math (``repro.core.services.encryption``)
as slot-loadable artifacts.  The CBC app reads the key from CSR 0 like the
paper's Code 1 (``cthread.setCSR(KEY, 0)``).
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core.port import PortCapabilities
from repro.core.services import encryption as E
from repro.core.services.base import ServiceRequirement
from repro.core.vfpga import AppArtifact

CSR_KEY_LO = 0
CSR_KEY_HI = 1


def _round_keys_from_csr(iface):
    lo = iface.csr.get_csr(CSR_KEY_LO, 0x0706050403020100)
    hi = iface.csr.get_csr(CSR_KEY_HI, 0x0F0E0D0C0B0A0908)
    key = np.frombuffer(np.array([lo, hi], dtype="<u8").tobytes(),
                        dtype=np.uint8).copy()
    return jnp.asarray(E.expand_key(key))


def aes_ecb_app(iface, vfpga, data):
    """ECB over a byte buffer — embarrassingly parallel, memory-bound."""
    rk = _round_keys_from_csr(iface)
    blocks = jnp.asarray(E.bytes_to_blocks(np.asarray(data)))
    out = E.aes_ecb(blocks, rk)
    return np.asarray(out).reshape(-1)


def aes_cbc_app(iface, vfpga, data, n_streams: int = 1):
    """CBC; with n_streams > 1 the buffer is split into independent
    cThread streams vmapped through the chained pipeline (Fig 10b)."""
    rk = _round_keys_from_csr(iface)
    blocks = E.bytes_to_blocks(np.asarray(data))
    n = blocks.shape[0] // n_streams * n_streams
    blocks = jnp.asarray(blocks[:n]).reshape(n_streams, -1, 16)
    ivs = jnp.zeros((n_streams, 16), jnp.uint8)
    out = E.aes_cbc_multistream(blocks, ivs, rk)
    return np.asarray(out).reshape(-1)


def make_aes_artifact(mode: str = "ecb") -> AppArtifact:
    fn = aes_ecb_app if mode == "ecb" else aes_cbc_app
    return AppArtifact(
        name=f"aes_{mode}", fn=fn,
        requires=[ServiceRequirement("encryption", {})],
        config_repr={"mode": mode},
        capabilities=PortCapabilities(
            name=f"aes_{mode}", kind="app", streams=1,
            csr_map={"key_lo": CSR_KEY_LO, "key_hi": CSR_KEY_HI},
            mem_model="host", ops=("local_transfer", "kernel")))
