"""Vector addition with MULTIPLE host streams (paper Fig 2, Req. 3).

The motivating interface example: prior shells force multiple inputs to be
packed into one stream in software; Coyote v2's parallel streams let each
vector ride its own stream.  This app consumes two input streams and
produces one output stream."""
from __future__ import annotations

import numpy as np

from repro.core.interfaces import Packet
from repro.core.port import PortCapabilities
from repro.core.vfpga import AppArtifact


def vector_add_app(iface, vfpga, a, b=None):
    """Two calling conventions: direct (a, b arrays) or streamed (pop one
    packet from host streams 0 and 1)."""
    if b is None:
        pa = iface.host_in[0].pop(timeout=1.0)
        pb = iface.host_in[1].pop(timeout=1.0)
        if pa is None or pb is None:
            raise RuntimeError("vector_add: missing input stream packet")
        a, b = pa.payload, pb.payload
    out = np.asarray(a, np.float32) + np.asarray(b, np.float32)
    iface.host_out[0].push(Packet(tid=0, seq_no=0, payload=out,
                                  nbytes=out.nbytes, last=True))
    return out


def make_vector_add_artifact() -> AppArtifact:
    return AppArtifact(name="vector_add", fn=vector_add_app,
                       config_repr={"streams": 2},
                       capabilities=PortCapabilities(
                           name="vector_add", kind="app", streams=2,
                           csr_map={}, mem_model="host",
                           ops=("local_transfer", "kernel")))


def passthrough_app(iface, vfpga, x):
    return x


def make_passthrough_artifact() -> AppArtifact:
    return AppArtifact(name="passthrough", fn=passthrough_app,
                       config_repr={},
                       capabilities=PortCapabilities(
                           name="passthrough", kind="app", streams=1,
                           csr_map={}, mem_model="host",
                           ops=("local_transfer", "kernel")))
