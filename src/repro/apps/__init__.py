"""vFPGA applications: the paper's evaluation workloads as slot-loadable
artifacts (AES ECB/CBC, HyperLogLog, NN inference, vector-add)."""
from repro.apps.aes import make_aes_artifact
from repro.apps.hll import (hll_count, hll_estimate, hll_merge, hll_sketch,
                            make_hll_artifact)
from repro.apps.nn_inference import CoyoteOverlay, MLPConfig, StagedCopyBaseline
from repro.apps.vector_add import make_passthrough_artifact, make_vector_add_artifact

__all__ = [
    "make_aes_artifact", "hll_count", "hll_estimate", "hll_sketch",
    "hll_merge", "make_hll_artifact", "CoyoteOverlay", "MLPConfig", "StagedCopyBaseline",
    "make_passthrough_artifact", "make_vector_add_artifact",
]
