"""HyperLogLog cardinality estimation in pure JAX (paper §9.6).

32-bit HLL: h1 selects the register (top p bits), rho = clz(h2)+1 is the
rank.  Registers merge with a scatter-max — on TPU this is a VPU-friendly
one-pass streaming sketch, matching the HLS kernel the paper deploys.
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _mix32(x: jnp.ndarray, seed: int) -> jnp.ndarray:
    """murmur3-style finalizer (uint32)."""
    x = x.astype(jnp.uint32) ^ jnp.uint32(seed)
    x ^= x >> 16
    x = x * jnp.uint32(0x85EBCA6B)
    x ^= x >> 13
    x = x * jnp.uint32(0xC2B2AE35)
    x ^= x >> 16
    return x


@functools.partial(jax.jit, static_argnames=("p",))
def hll_sketch(items: jnp.ndarray, *, p: int = 12) -> jnp.ndarray:
    """items (N,) int -> registers (2^p,) uint8."""
    m = 1 << p
    h1 = _mix32(items, 0x9E3779B9)
    h2 = _mix32(items, 0x85EBCA77)
    idx = (h1 >> (32 - p)).astype(jnp.int32)
    rho = (jax.lax.clz(h2.astype(jnp.int32) | jnp.int32(1)) + 1
           ).astype(jnp.uint8)                       # 1..32
    regs = jnp.zeros((m,), jnp.uint8)
    return regs.at[idx].max(rho)


@functools.partial(jax.jit, static_argnames=("p",))
def hll_merge(a: jnp.ndarray, b: jnp.ndarray, *, p: int = 12) -> jnp.ndarray:
    return jnp.maximum(a, b)


@functools.partial(jax.jit, static_argnames=("p",))
def hll_estimate(regs: jnp.ndarray, *, p: int = 12) -> jnp.ndarray:
    m = 1 << p
    alpha = 0.7213 / (1 + 1.079 / m)
    inv = jnp.sum(jnp.exp2(-regs.astype(jnp.float32)))
    raw = alpha * m * m / inv
    zeros = jnp.sum(regs == 0).astype(jnp.float32)
    linear = m * jnp.log(m / jnp.maximum(zeros, 1.0))
    return jnp.where((raw <= 2.5 * m) & (zeros > 0), linear, raw)


def hll_count(items, *, p: int = 12) -> float:
    return float(hll_estimate(hll_sketch(jnp.asarray(items), p=p), p=p))


# ---- vFPGA app wrapper -----------------------------------------------------
@dataclass(frozen=True)
class HLLConfig:
    p: int = 12


def hll_app_fn(iface, vfpga, data):
    """User logic for the vFPGA slot: consume a stream buffer, return the
    cardinality estimate (raised to host via the interrupt channel too).
    The byte buffer is reinterpreted as uint32 items with zero host-side
    conversion cost (a view, not a copy)."""
    items = jnp.asarray(np.asarray(data).view(np.uint32))
    est = hll_count(items)
    iface.irq.raise_irq(int(est) & 0x7FFFFFFF)
    return est


def make_hll_artifact():
    from repro.core.port import PortCapabilities
    from repro.core.services.base import ServiceRequirement
    from repro.core.vfpga import AppArtifact
    return AppArtifact(name="hll", fn=hll_app_fn,
                       requires=[ServiceRequirement("mmu", {})],
                       config_repr=HLLConfig(),
                       capabilities=PortCapabilities(
                           name="hll", kind="app", streams=1, csr_map={},
                           mem_model="host",
                           ops=("local_transfer", "kernel")))
