"""LM serving as a vFPGA app: the paper's Fig 1 end-to-end.

The serving engine (continuous batching on the MMU's paged KV) mounts in a
shell slot behind the unified interface: cThreads submit prompts through
``invoke``, the engine fills the decode pipeline across concurrent TIDs,
completions raise user interrupts, and CSRs control sampling.

    shell = Shell(ShellConfig.make(services={"mmu": MMUConfig(...)}))
    shell.build()
    shell.load_app(0, make_lm_serving_artifact(cfg, params))
    ct = shell.attach_thread(0, pid)
    out = ct.invoke(Oper.KERNEL, SgEntry(src=prompt_ids, meta={...}))
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.port import PortCapabilities
from repro.core.services.base import ServiceRequirement
from repro.core.vfpga import AppArtifact

CSR_TEMPERATURE_MILLI = 0x10      # temperature * 1000
CSR_MAX_NEW_TOKENS = 0x11
CSR_TOP_K = 0x12                  # 0 = disabled
CSR_TOP_P_MILLI = 0x13            # top_p * 1000; 0 or >=1000 = disabled


class _EngineHolder:
    """Lazily builds one ServingEngine per vFPGA slot, bound to the
    shell's MMU service (the app 'links against' the service)."""

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 max_len: int = 256):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self._engines: Dict[int, Any] = {}

    def engine(self, vfpga):
        slot = vfpga.slot
        eng = self._engines.get(slot)
        if eng is None:
            from repro.serve.engine import ServingEngine
            mmu = vfpga.shell.services.get("mmu")
            if mmu is None:
                raise RuntimeError("lm_serving requires the mmu service")
            eng = self._engines[slot] = ServingEngine(
                self.cfg, self.params, mmu, max_batch=self.max_batch,
                max_len=self.max_len, shell=vfpga.shell, slot=slot,
                tenant=vfpga.tenant)
        elif vfpga.shell.engines.get(slot) is not eng:
            # the slot was hot-swapped away and back: rebind the cached
            # engine (unload() released its registrations).  Guarded so
            # steady-state requests skip the registry write and the
            # pager re-registration (this runs per invocation).
            vfpga.shell.engines[slot] = eng
            eng.mmu.register_pager(eng._pager_gather, eng._pager_scatter,
                                   owner=eng)
        return eng

    def __call__(self, iface, vfpga, prompt) -> List[int]:
        eng = self.engine(vfpga)
        temp = iface.csr.get_csr(CSR_TEMPERATURE_MILLI, 0) / 1000.0
        max_new = iface.csr.get_csr(CSR_MAX_NEW_TOKENS, 8)
        top_k = iface.csr.get_csr(CSR_TOP_K, 0)
        top_p_milli = iface.csr.get_csr(CSR_TOP_P_MILLI, 0)
        top_p = top_p_milli / 1000.0 if 0 < top_p_milli < 1000 else 1.0
        toks = np.asarray(prompt).reshape(-1)
        toks = toks.view(np.int32) if toks.dtype == np.uint8 else toks
        rid = eng.submit([int(t) for t in toks if t > 0],
                         max_new_tokens=int(max_new), temperature=temp,
                         top_k=int(top_k), top_p=top_p)
        while eng.pending():
            eng.step()
            # decode-step checkpoint: a long serve loop on this slot's
            # lane yields here to higher-priority granted work
            vfpga.checkpoint()
        req = next(r for r in eng.completed if r.rid == rid)
        iface.irq.raise_irq(rid)           # completion interrupt
        return req.out_tokens


def make_lm_serving_artifact(cfg: ModelConfig, params, *,
                             max_batch: int = 4,
                             max_len: int = 256) -> AppArtifact:
    holder = _EngineHolder(cfg, params, max_batch=max_batch,
                           max_len=max_len)
    return AppArtifact(
        name="lm_serving",
        fn=holder,
        requires=[ServiceRequirement("mmu", {"min_page_size": 1})],
        config_repr={"arch": cfg.arch_id, "max_batch": max_batch,
                     "max_len": max_len},
        capabilities=PortCapabilities(
            name="lm_serving", kind="app", streams=max_batch,
            csr_map={"temperature_milli": CSR_TEMPERATURE_MILLI,
                     "max_new_tokens": CSR_MAX_NEW_TOKENS,
                     "top_k": CSR_TOP_K,
                     "top_p_milli": CSR_TOP_P_MILLI},
            mem_model="paged", ops=("kernel",)))
