"""Fault-tolerant checkpointing: sharded-state save/restore with async
writes, atomic publication, retention, and *elastic* restore.

Layout per step:  <dir>/step_<N>/manifest.json + <path-hash>.npy per leaf.
Leaves are written as full logical arrays (gathered), so a checkpoint is
mesh-agnostic: restore re-shards onto any device count — the elastic
re-mesh path (DESIGN.md §4).  Publication is atomic (tmp dir + rename);
an interrupted save can never corrupt the latest checkpoint.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional

import numpy as np

import jax


def _path_key(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3,
                 async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self.save_count = 0

    # ------------------------------------------------------------- save ----
    def save(self, step: int, state: Any, *, fingerprint: str = "",
             blocking: bool = False) -> None:
        # snapshot to host synchronously (cheap view), write in background
        flat = jax.tree_util.tree_flatten_with_path(state)[0]
        host = [(_path_key(p), np.asarray(jax.device_get(x)))
                for p, x in flat]
        if self.async_save and not blocking:
            self.wait()                       # at most one in-flight save
            self._thread = threading.Thread(
                target=self._write, args=(step, host, fingerprint),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, host, fingerprint)

    def _write(self, step: int, host, fingerprint: str) -> None:
        tmp = self.dir / f".tmp_step_{step}_{os.getpid()}"
        final = self.dir / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "fingerprint": fingerprint,
                    "created": time.time(), "leaves": {}}
        for key, arr in host:
            fname = hashlib.sha1(key.encode()).hexdigest()[:16] + ".npy"
            np.save(tmp / fname, arr)
            manifest["leaves"][key] = {
                "file": fname, "shape": list(arr.shape),
                "dtype": str(arr.dtype)}
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)                 # atomic publication
        self.save_count += 1
        self._retain()

    def wait(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    def _retain(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # ---------------------------------------------------------- restore ----
    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, *, step: Optional[int] = None,
                shardings: Any = None,
                expect_fingerprint: str = "") -> Any:
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings``: optional matching pytree — the
        elastic path re-shards onto whatever mesh they name."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step}"
        manifest = json.loads((d / "manifest.json").read_text())
        if expect_fingerprint and manifest["fingerprint"] != expect_fingerprint:
            raise ValueError(
                f"checkpoint fingerprint {manifest['fingerprint']!r} != "
                f"expected {expect_fingerprint!r}")

        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        shard_flat = (jax.tree.leaves(shardings)
                      if shardings is not None else [None] * len(flat))
        leaves = []
        for (path, leaf_like), shard in zip(flat, shard_flat):
            key = _path_key(path)
            meta = manifest["leaves"].get(key)
            if meta is None:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            arr = np.load(d / meta["file"])
            if shard is not None:
                leaves.append(jax.device_put(arr, shard))
            else:
                leaves.append(jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves), step
