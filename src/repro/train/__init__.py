from repro.train.loop import SimulatedFailure, TrainConfig, Trainer
__all__ = ["SimulatedFailure", "TrainConfig", "Trainer"]
