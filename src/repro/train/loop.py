"""Trainer: fault-tolerant, straggler-mitigating training loop.

Production behaviours exercised here (and tested in tests/test_train.py):

  * checkpoint/restart — async sharded checkpoints every ``ckpt_every``;
    on (injected) failure the loop restores the latest checkpoint and
    continues bit-identically (the data pipeline is pure in step);
  * elastic re-mesh — checkpoints are mesh-agnostic; ``Trainer.restore``
    re-shards onto whatever mesh the new process owns;
  * straggler mitigation — the prefetcher feeds through a timeout; a
    straggling host's batch is skipped (logged) instead of stalling the
    step barrier;
  * gradient compression — optional GradCompression service (int8 + error
    feedback) on the DP-reduce path;
  * microbatching — gradient accumulation via lax.scan inside the step.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.faults import FaultKind, InjectedFault, maybe_fire
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticCorpus
from repro.launch.steps import make_train_bundle
from repro.models import transformer as T
from repro.models.sharding import MeshRules
from repro.optim import adamw


class SimulatedFailure(InjectedFault):
    """Injected whole-node failure — the trainer's member of the ONE
    shared fault taxonomy (``FaultKind.NODE_FAILURE``, site
    ``train.step``).  Message-positional construction is preserved for
    existing callers; the richer serving-side plans arm the same kind
    through ``TrainConfig.fault_plan`` instead."""

    def __init__(self, message: str = "", **kw: Any):
        kw.setdefault("kind", FaultKind.NODE_FAILURE)
        kw.setdefault("site", "train.step")
        kw.setdefault("retryable", False)
        super().__init__(message, **kw)


@dataclass
class TrainConfig:
    steps: int = 50
    log_every: int = 10
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/coyote_ckpt"
    keep: int = 3
    microbatches: int = 1
    remat: str = "none"
    compute_dtype: Any = None
    param_dtype: Any = jnp.float32
    seed: int = 0
    batch_timeout_s: float = 5.0      # straggler skip threshold
    fail_at_step: int = -1            # inject a failure once at this step
    # richer injection: a seeded repro.core.faults.FaultPlan probed once
    # per step at site "train.step" (same taxonomy as the serving shell;
    # ``fail_at_step`` is sugar for one NODE_FAILURE at a fixed step)
    fault_plan: Any = None
    straggler_steps: tuple = ()       # steps whose host batch is slow
    straggler_delay_s: float = 0.0
    compression: Any = None           # GradCompression service or None
    opt: adamw.AdamWConfig = field(default_factory=adamw.AdamWConfig)


class Trainer:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig,
                 tcfg: TrainConfig, mesh=None):
        self.cfg = cfg
        self.shape = shape
        self.tcfg = tcfg
        self.mesh = mesh
        self.rules = (MeshRules.from_mesh(mesh) if mesh is not None
                      else MeshRules.single_device())
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep)
        self.metrics_log: List[Dict[str, float]] = []
        self.skipped_steps: List[int] = []
        self._build()

    # ------------------------------------------------------------ build ----
    def _fingerprint(self) -> str:
        return f"{self.cfg.arch_id}|{self.shape.name}|{self.tcfg.seed}"

    def _build(self) -> None:
        cfg, shape, tcfg = self.cfg, self.shape, self.tcfg
        if self.mesh is not None:
            bundle = make_train_bundle(
                cfg, shape, self.mesh, remat=tcfg.remat,
                compute_dtype=tcfg.compute_dtype, opt_cfg=tcfg.opt,
                param_dtype=tcfg.param_dtype,
                microbatches=tcfg.microbatches,
                compression=tcfg.compression)
            self.step_fn = bundle.jitted()
        else:
            def train_step(params, opt_state, batch):
                def lf(p):
                    return T.loss_fn(p, cfg, batch, remat=tcfg.remat,
                                     compute_dtype=tcfg.compute_dtype)
                (_, metrics), grads = jax.value_and_grad(
                    lf, has_aux=True)(params)
                opt_state = dict(opt_state)
                if tcfg.compression is not None:
                    ef = opt_state.pop("ef", None)
                    grads, new_ef, _ = tcfg.compression.apply(grads, ef)
                new_params, new_opt, om = adamw.update(
                    grads, opt_state, params, tcfg.opt)
                if tcfg.compression is not None and new_ef is not None:
                    new_opt["ef"] = new_ef
                m = dict(metrics)
                m.update(om)
                return new_params, new_opt, m
            self.step_fn = jax.jit(train_step, donate_argnums=(0, 1))

        self.params = T.init_params(jax.random.PRNGKey(tcfg.seed), cfg,
                                    dtype=tcfg.param_dtype)
        self.opt_state = adamw.init(self.params)
        if tcfg.compression is not None and \
                tcfg.compression.config.error_feedback:
            self.opt_state["ef"] = tcfg.compression.init_state(self.params)
        self.step = 0

        dcfg = DataConfig(
            seq_len=shape.seq_len, global_batch=shape.global_batch,
            vocab_size=cfg.vocab_size, seed=tcfg.seed,
            with_frames=cfg.n_encoder_layers > 0,
            frame_len=cfg.encoder_seq_len, d_model=cfg.d_model)
        self.corpus = SyntheticCorpus(dcfg)
        self._start_prefetch(0)

    def _start_prefetch(self, start_step: int) -> None:
        tcfg = self.tcfg
        slow = set(tcfg.straggler_steps)

        def straggler(step: int) -> float:
            return tcfg.straggler_delay_s if step in slow else 0.0

        self.prefetch = Prefetcher(
            self.corpus, depth=2,
            straggler_sim=straggler if slow else None,
            start_step=start_step)

    # ------------------------------------------------------------- run -----
    def run(self) -> Dict[str, Any]:
        tcfg = self.tcfg
        t0 = time.perf_counter()
        restarts = 0
        while self.step < tcfg.steps:
            try:
                self._run_inner()
            except InjectedFault:        # any typed fault kind restarts
                restarts += 1
                self.prefetch.stop()
                self.restore()                 # checkpoint/restart path
                self._start_prefetch(self.step)
        self.prefetch.stop()
        self.ckpt.wait()
        return {
            "final_step": self.step,
            "restarts": restarts,
            "skipped_steps": self.skipped_steps,
            "wall_s": time.perf_counter() - t0,
            "final_loss": (self.metrics_log[-1]["loss"]
                           if self.metrics_log else float("nan")),
        }

    def _run_inner(self) -> None:
        tcfg = self.tcfg
        while self.step < tcfg.steps:
            if self.step == tcfg.fail_at_step:
                tcfg.fail_at_step = -1          # fire once
                raise SimulatedFailure(f"injected at step {self.step}")
            maybe_fire(tcfg.fault_plan, "train.step")
            got = self.prefetch.get(timeout=tcfg.batch_timeout_s)
            if got is None:                     # straggler: skip dispatch
                self.skipped_steps.append(self.step)
                continue
            data_step, batch = got
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            self.step += 1
            if self.step % tcfg.log_every == 0 or self.step == tcfg.steps:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = self.step
                self.metrics_log.append(m)
            if tcfg.ckpt_every and self.step % tcfg.ckpt_every == 0:
                self.save()

    # ------------------------------------------------------ checkpointing ---
    def save(self, blocking: bool = False) -> None:
        state = {"params": self.params, "opt": self.opt_state,
                 "step": jnp.int32(self.step)}
        self.ckpt.save(self.step, state, fingerprint=self._fingerprint(),
                       blocking=blocking)

    def restore(self, step: Optional[int] = None) -> None:
        like = {"params": self.params, "opt": self.opt_state,
                "step": jnp.int32(0)}
        state, at = self.ckpt.restore(like, step=step,
                                      expect_fingerprint=self._fingerprint())
        self.params = state["params"]
        self.opt_state = state["opt"]
        self.step = int(state["step"])
