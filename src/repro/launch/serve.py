"""Serving launcher: paged continuous-batching engine over the MMU service.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
        --requests 16 --max-new 16 --batch 8
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.services.mmu import MMU, MMUConfig
from repro.models import transformer as T
from repro.serve.engine import ServingEngine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--page-size", type=int, default=32)
    ap.add_argument("--n-pages", type=int, default=512)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = T.init_params(jax.random.PRNGKey(args.seed), cfg,
                           dtype=jnp.float32)
    mmu = MMU(MMUConfig(page_size=args.page_size, n_pages=args.n_pages))
    eng = ServingEngine(cfg, params, mmu, max_batch=args.batch,
                        max_len=args.max_len, seed=args.seed)

    rng = np.random.RandomState(args.seed)
    for _ in range(args.requests):
        plen = int(rng.randint(4, 48))
        eng.submit(rng.randint(3, cfg.vocab_size, size=plen).tolist(),
                   max_new_tokens=args.max_new,
                   temperature=args.temperature)
    stats = eng.run()
    lat = [r.t_first_token - r.t_submit for r in eng.completed]
    stats["ttft_p50_s"] = float(np.percentile(lat, 50)) if lat else 0.0
    stats["mmu"] = eng.mmu.utilization()
    print(json.dumps(stats, indent=1, default=str))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
