"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — smoke tests must keep seeing 1 CPU device,
while the dry-run initialises 512 placeholder devices before calling in.

Mesh construction goes through :mod:`repro.compat` so installs without
``jax.sharding.AxisType`` (older JAX) still work — Auto is the implicit
default there.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro import compat


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: (data=16, model=16) = 256 chips; two pods add a leading
    `pod` axis (512 chips).  DP/FSDP runs on (pod, data); TP/EP/SP on model."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes,
                            axis_types=compat.auto_axis_types(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over whatever local devices exist (tests, examples).

    Raises a descriptive :class:`RuntimeError` (NOT a bare assert) when
    the process does not expose enough devices, so multi-device tests can
    ``pytest.skip`` on the message instead of erroring.  On CPU, force
    extra host devices with::

        XLA_FLAGS=--xla_force_host_platform_device_count=N

    set in the environment BEFORE jax is imported.
    """
    n = data * model
    devs = jax.devices()[:n]
    if len(devs) != n:
        raise RuntimeError(
            f"make_host_mesh(data={data}, model={model}) needs {n} "
            f"devices but this process sees {len(jax.devices())}; on CPU "
            f"set XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            "before importing jax (subprocess-style, see "
            "tests/test_mesh_serving.py and docs/sharding.md)")
    return compat.make_mesh((data, model), ("data", "model"),
                            axis_types=compat.auto_axis_types(2),
                            devices=devs)


def mesh_chips(mesh: Mesh) -> int:
    n = 1
    for s in mesh.shape.values():
        n *= s
    return n
