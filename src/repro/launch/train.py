"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 200 --seq-len 128 --batch 8 [--reduced] [--compress]

On this CPU container, --reduced (default) trains the reduced config of the
chosen architecture; full configs are for real pods (see launch/dryrun.py
for the compile-only path).  The end-to-end ~100M-parameter run from the
deliverables is ``examples/train_smollm.py`` (smollm-135m IS ~135M params,
trained here at full width with shortened depth if --layers is given).
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.services.compression import (CompressionConfig,
                                             GradCompression)
from repro.optim.adamw import AdamWConfig
from repro.train.loop import TrainConfig, Trainer


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--layers", type=int, default=0,
                    help="override layer count (0 = config value)")
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced (smoke) config")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none",
                    choices=["none", "dots", "full"])
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--fail-at", type=int, default=-1)
    ap.add_argument("--ckpt-dir", default="/tmp/coyote_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.layers:
        cfg = dataclasses.replace(cfg, n_layers=args.layers)
    shape = ShapeConfig("cli_train", "train", args.seq_len, args.batch)

    comp = (GradCompression(CompressionConfig(bits=8, error_feedback=True))
            if args.compress else None)
    tcfg = TrainConfig(
        steps=args.steps, log_every=max(args.steps // 20, 1),
        ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir,
        microbatches=args.microbatches, remat=args.remat,
        seed=args.seed, fail_at_step=args.fail_at, compression=comp,
        opt=AdamWConfig(lr=args.lr, total_steps=args.steps))

    trainer = Trainer(cfg, shape, tcfg)
    result = trainer.run()
    print(json.dumps({"result": result,
                      "log": trainer.metrics_log[-5:]}, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
