import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay the first statements in this module — jax
locks the device count at first init, and the production meshes need 512
placeholder host devices.  Never import this module from tests/benches
(they must see 1 device); it is a CLI:

    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m \
        --shape train_4k --mesh pod

Results (memory analysis, cost analysis, collective schedule, roofline
terms) are written incrementally to experiments/dryrun/<mesh>/<arch>__<shape>.json
so the 40-cell × 2-mesh sweep is resumable.
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.compat import normalize_cost_analysis
from repro.configs import ALL_SHAPES, ARCHS, get_config, get_shape, shape_applicable
from repro.launch.mesh import make_production_mesh, mesh_chips
from repro.launch.steps import make_bundle
from repro.telemetry import roofline as R

DEFAULT_OUT = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def run_cell(arch_id: str, shape_name: str, mesh_kind: str, *,
             out_dir: Path = DEFAULT_OUT, force: bool = False,
             bundle_kw=None, tag: str = "") -> dict:
    cfg = get_config(arch_id)
    shape = get_shape(shape_name)
    out_path = out_dir / mesh_kind / f"{arch_id}__{shape_name}{tag}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_kind,
           "tag": tag, "status": "pending"}
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        rec.update(status="skipped", reason=why)
        _write(out_path, rec)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    chips = mesh_chips(mesh)
    try:
        with mesh:
            bundle = make_bundle(cfg, shape, mesh, **(bundle_kw or {}))
            t0 = time.perf_counter()
            lowered = bundle.lower()
            t1 = time.perf_counter()
            compiled = lowered.compile()
            t2 = time.perf_counter()

        mem = R.memory_stats(compiled)
        print(f"[{arch_id}/{shape_name}/{mesh_kind}] memory_analysis:", mem)
        ca = normalize_cost_analysis(compiled.cost_analysis())
        print(f"[{arch_id}/{shape_name}/{mesh_kind}] cost_analysis: "
              f"flops={ca.get('flops', 0):.3e} "
              f"bytes={ca.get('bytes accessed', 0):.3e}")

        mf = R.model_flops_for(cfg, shape)
        fused = (bundle_kw or {}).get("attention_impl") == "fused"
        extra = R.fused_boundary_bytes(cfg, shape, chips) if fused else 0.0
        roof = R.analyze(
            compiled, chips=chips, model_flops=mf,
            discount_scope="vmem_fused" if fused else None,
            extra_bytes_per_device=extra)
        rec.update(
            status="ok",
            step=bundle.name,
            bundle_kw={k: str(v) for k, v in (bundle_kw or {}).items()},
            chips=chips,
            lower_s=t1 - t0,
            compile_s=t2 - t1,
            memory_analysis=mem,
            cost_analysis={k: float(v) for k, v in ca.items()
                           if isinstance(v, (int, float))},
            roofline=roof.as_dict(),
        )
    except Exception as e:  # a failing cell is a bug in our sharding
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-4000:])
    _write(out_path, rec)
    return rec


def _write(path: Path, rec: dict):
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(rec, indent=1))


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", type=Path, default=DEFAULT_OUT)
    args = ap.parse_args(argv)

    archs = sorted(ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = ([s.name for s in ALL_SHAPES] if args.shape == "all"
              else args.shape.split(","))
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    n_ok = n_skip = n_err = 0
    for mesh_kind in meshes:
        for arch in archs:
            for shape in shapes:
                t0 = time.perf_counter()
                rec = run_cell(arch, shape, mesh_kind, out_dir=args.out,
                               force=args.force)
                jax.clear_caches()
                dt = time.perf_counter() - t0
                st = rec["status"]
                n_ok += st == "ok"
                n_skip += st == "skipped"
                n_err += st == "error"
                extra = ""
                if st == "ok":
                    r = rec["roofline"]
                    extra = (f"dom={r['dominant']} "
                             f"c={r['compute_s']:.3e}s m={r['memory_s']:.3e}s "
                             f"x={r['collective_s']:.3e}s "
                             f"frac={r['roofline_fraction']:.3f}")
                elif st == "error":
                    extra = rec["error"][:120]
                print(f"{st.upper():7s} {mesh_kind}/{arch}/{shape} "
                      f"({dt:.1f}s) {extra}", flush=True)
    print(f"done: ok={n_ok} skipped={n_skip} error={n_err}")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
