"""Step builders shared by the dry-run, the trainer, and the server.

Each builder returns a :class:`StepBundle`: the step function plus abstract
inputs (ShapeDtypeStructs — no allocation) and sharding trees, ready for
``jax.jit(fn, in_shardings=…).lower(*abstract).compile()``.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as T
from repro.models.sharding import MeshRules
from repro.optim import adamw


@dataclass
class StepBundle:
    name: str
    fn: Callable
    abstract_args: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    static_broadcast: Tuple[int, ...] = ()

    def jitted(self):
        return jax.jit(self.fn, in_shardings=self.in_shardings,
                       out_shardings=self.out_shardings,
                       donate_argnums=self.donate_argnums)

    def lower(self):
        return self.jitted().lower(*self.abstract_args)


def _ns(mesh: Mesh, tree):
    return jax.tree.map(lambda sp: NamedSharding(mesh, sp), tree,
                        is_leaf=lambda x: isinstance(x, P))


def _abstract_params(cfg: ModelConfig, dtype):
    return jax.eval_shape(
        functools.partial(T.init_params, cfg=cfg, dtype=dtype),
        jax.random.PRNGKey(0))


def _batch_abstract(cfg: ModelConfig, shape: ShapeConfig):
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.n_encoder_layers:
        batch["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
    return batch


def _batch_specs(cfg: ModelConfig, shape: ShapeConfig, rules: MeshRules):
    bax = rules.batch(shape.global_batch)
    specs = {"tokens": P(bax, None)}
    if cfg.n_encoder_layers:
        specs["frames"] = P(bax, None, None)
    return specs


# ================================================================= train ===
def make_train_bundle(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, *,
                      remat: str = "dots",
                      compute_dtype=jnp.bfloat16,
                      opt_cfg: Optional[adamw.AdamWConfig] = None,
                      param_dtype=jnp.float32,
                      microbatches: int = 1,
                      compression=None,
                      attention_impl: str = "ref",
                      param_scheme: str = "2d",
                      cast_params_bf16: bool = False) -> StepBundle:
    """``microbatches`` > 1 accumulates gradients over sequential
    micro-steps (memory lever); ``compression`` is an optional
    GradCompression service whose error-feedback state rides in
    opt_state["ef"] (inter-pod bandwidth lever)."""
    rules = MeshRules.from_mesh(mesh, scheme=param_scheme)
    opt_cfg = opt_cfg or adamw.AdamWConfig()
    assert shape.global_batch % microbatches == 0

    fused = attention_impl == "fused"

    def loss_grads(p, b):
        def lf(p):
            if cast_params_bf16:
                # cast BEFORE the FSDP gathers so they move bf16, not f32
                # (grads flow through the cast and accumulate fp32)
                p_use = jax.tree.map(
                    lambda w: w.astype(jnp.bfloat16)
                    if w.dtype == jnp.float32 and w.ndim >= 2 else w, p)
            else:
                p_use = p
            return T.loss_fn(p_use, cfg, b, remat=remat, rules=rules,
                             compute_dtype=compute_dtype,
                             fused_attention=fused)
        return jax.value_and_grad(lf, has_aux=True)(p)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (_, metrics), grads = loss_grads(params, batch)
        else:
            resh = jax.tree.map(
                lambda x: x.reshape(
                    (microbatches, x.shape[0] // microbatches)
                    + x.shape[1:]), batch)

            def body(carry, mb):
                gacc, macc = carry
                (_, m), g = loss_grads(params, mb)
                gacc = jax.tree.map(jnp.add, gacc, g)
                macc = jax.tree.map(jnp.add, macc, m)
                return (gacc, macc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            m0 = {"loss": jnp.float32(0), "aux_loss": jnp.float32(0),
                  "tokens": jnp.float32(0)}
            (gsum, msum), _ = jax.lax.scan(body, (g0, m0), resh)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            metrics = {"loss": msum["loss"] / microbatches,
                       "aux_loss": msum["aux_loss"] / microbatches,
                       "tokens": msum["tokens"]}
        opt_state = dict(opt_state)
        if compression is not None:
            ef = opt_state.pop("ef", None)
            grads, new_ef, _ = compression.apply(grads, ef)
        new_params, new_opt, om = adamw.update(grads, opt_state, params,
                                               opt_cfg)
        if compression is not None and new_ef is not None:
            new_opt["ef"] = new_ef
        metrics = dict(metrics)
        metrics.update(om)
        return new_params, new_opt, metrics

    params_abs = _abstract_params(cfg, param_dtype)
    opt_abs = jax.eval_shape(adamw.init, params_abs)
    if compression is not None and compression.config.error_feedback:
        opt_abs = dict(opt_abs)
        opt_abs["ef"] = jax.eval_shape(compression.init_state, params_abs)
    batch_abs = _batch_abstract(cfg, shape)

    pspec = T.param_specs(cfg, rules)
    ospec = adamw.state_specs(pspec)
    if compression is not None and compression.config.error_feedback:
        ospec = dict(ospec)
        ospec["ef"] = pspec
    bspec = _batch_specs(cfg, shape, rules)

    return StepBundle(
        name=f"train[{cfg.arch_id}/{shape.name}]",
        fn=train_step,
        abstract_args=(params_abs, opt_abs, batch_abs),
        in_shardings=(_ns(mesh, pspec), _ns(mesh, ospec), _ns(mesh, bspec)),
        out_shardings=(_ns(mesh, pspec), _ns(mesh, ospec),
                       NamedSharding(mesh, P())),
        donate_argnums=(0, 1),
    )


# =============================================================== prefill ===
def make_prefill_bundle(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, *,
                        param_dtype=jnp.bfloat16,
                        cache_dtype=jnp.bfloat16,
                        attention_impl: str = "ref",
                        serving_params: bool = False) -> StepBundle:
    rules = MeshRules.from_mesh(mesh)
    if serving_params:
        rules = rules.serving()
    max_len = shape.seq_len
    fused = attention_impl == "fused"

    def prefill_step(params, batch):
        return T.prefill(params, cfg, batch["tokens"], max_len,
                         encoder_frames=batch.get("frames"), rules=rules,
                         cache_dtype=cache_dtype, fused_attention=fused)

    params_abs = _abstract_params(cfg, param_dtype)
    batch_abs = _batch_abstract(cfg, shape)
    pspec = T.param_specs(cfg, rules)
    bspec = _batch_specs(cfg, shape, rules)
    cspec = T.cache_specs(cfg, rules, shape.global_batch, max_len)
    logits_spec = P(rules.batch(shape.global_batch),
                    rules.tp(cfg.padded_vocab))

    return StepBundle(
        name=f"prefill[{cfg.arch_id}/{shape.name}]",
        fn=prefill_step,
        abstract_args=(params_abs, batch_abs),
        in_shardings=(_ns(mesh, pspec), _ns(mesh, bspec)),
        out_shardings=(NamedSharding(mesh, logits_spec), _ns(mesh, cspec)),
        donate_argnums=(),
    )


# ================================================================ decode ===
def make_decode_bundle(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, *,
                       param_dtype=jnp.bfloat16,
                       cache_dtype=jnp.bfloat16,
                       attention_impl: str = "ref",
                       uniform_pos: bool = False,
                       context_parallel: bool = False,
                       serving_params: bool = False) -> StepBundle:
    rules = MeshRules.from_mesh(mesh)
    if serving_params:
        rules = rules.serving()       # TP-only weights: no FSDP gathers
    b = shape.global_batch
    max_len = shape.seq_len
    fused = attention_impl == "fused"
    # context-parallel decode only applies when the cache is seq-sharded
    kl = T.decode_cache_len(cfg, max_len)
    cp = (mesh if context_parallel and rules.tp(cfg.n_kv_heads) is None
          and rules.tp_size and kl % rules.tp_size == 0 else None)

    def serve_step(params, cache, tokens, pos):
        return T.decode_step(params, cfg, cache, tokens, pos,
                             fused_attention=fused,
                             uniform_pos=uniform_pos, cp_mesh=cp)

    params_abs = _abstract_params(cfg, param_dtype)
    cache_abs = jax.eval_shape(
        functools.partial(T.init_cache, cfg, b, max_len, dtype=cache_dtype,
                          enc_seq=cfg.encoder_seq_len))
    tok_abs = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    pos_abs = jax.ShapeDtypeStruct((b,), jnp.int32)

    pspec = T.param_specs(cfg, rules)
    cspec = T.cache_specs(cfg, rules, b, max_len)
    bax = rules.batch(b)
    logits_spec = P(bax, rules.tp(cfg.padded_vocab))

    return StepBundle(
        name=f"decode[{cfg.arch_id}/{shape.name}]",
        fn=serve_step,
        abstract_args=(params_abs, cache_abs, tok_abs, pos_abs),
        in_shardings=(_ns(mesh, pspec), _ns(mesh, cspec),
                      NamedSharding(mesh, P(bax, None)),
                      NamedSharding(mesh, P(bax))),
        out_shardings=(NamedSharding(mesh, logits_spec), _ns(mesh, cspec)),
        donate_argnums=(1,),
    )


def make_bundle(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                **kw) -> StepBundle:
    if shape.kind == "train":
        return make_train_bundle(cfg, shape, mesh, **kw)
    if shape.kind == "prefill":
        return make_prefill_bundle(cfg, shape, mesh, **kw)
    return make_decode_bundle(cfg, shape, mesh, **kw)
