#!/usr/bin/env python
"""Line-coverage gate for the migration/fleet control plane.

``pytest-cov``/``coverage`` are not installable in this environment, so
the gate drives stdlib ``trace.Trace(count=1)`` over the fleet and
pre-copy test files in-process and computes executed-line fractions per
control-plane module (executable line sets come from
``trace._find_executable_linenos`` — the same oracle ``trace`` itself
uses for its coverage listings).  Exits non-zero when the AGGREGATE
coverage over the targets drops below ``--min``, so a PR cannot grow
the migration surface without the property/fuzz layer reaching it.

    PYTHONPATH=src python scripts/coverage_gate.py [--min PCT]

Only control-plane (pure-Python) modules are gated: jitted kernel
bodies execute outside the interpreter after compilation, so their
line counts would be trace-time artifacts, not coverage.
"""
from __future__ import annotations

import argparse
import os
import sys
import trace

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# the migration/recovery surface the fleet test layer is responsible
# for.  No __init__.py: stdlib trace's ignore cache keys by BARE module
# name, so once any stdlib "__init__" under sys.prefix is ignored,
# every package __init__ is — their counts are unmeasurable here.
TARGETS = [
    "src/repro/fleet/controller.py",
    "src/repro/core/migrate.py",
    "src/repro/core/services/mmu.py",
    "src/repro/core/bitstream.py",
]
TESTS = ["tests/test_fleet_fuzz.py", "tests/test_precopy.py"]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    # measured on the seed run: 75.8% aggregate (controller 78%, mmu
    # 81%, migrate 69%, bitstream 71%); the floor sits 10pts under that
    ap.add_argument("--min", type=float, default=65.0,
                    help="aggregate coverage floor over TARGETS (pct)")
    args = ap.parse_args(argv)

    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    os.chdir(REPO)
    src = os.path.join(REPO, "src")
    if src not in sys.path:
        sys.path.insert(0, src)
    import pytest

    tracer = trace.Trace(count=1, trace=0,
                         ignoredirs=[sys.prefix, sys.exec_prefix])
    status = []
    tracer.runfunc(lambda: status.append(pytest.main(["-x", "-q"] + TESTS)))
    if status[0] != 0:
        print(f"[coverage-gate] gated tests FAILED (pytest exit "
              f"{status[0]})")
        return 1

    hit = {}
    for (fname, lineno), n in tracer.results().counts.items():
        if n > 0:
            hit.setdefault(os.path.abspath(fname), set()).add(lineno)

    print(f"\n{'module':<40} {'lines':>6} {'hit':>6} {'cov%':>7}")
    tot_lines = tot_hit = 0
    for rel in TARGETS:
        path = os.path.abspath(os.path.join(REPO, rel))
        execable = set(trace._find_executable_linenos(path))
        got = len(execable & hit.get(path, set()))
        tot_lines += len(execable)
        tot_hit += got
        pct = 100.0 * got / max(len(execable), 1)
        print(f"{rel:<40} {len(execable):>6} {got:>6} {pct:>6.1f}%")

    pct = 100.0 * tot_hit / max(tot_lines, 1)
    print(f"{'TOTAL':<40} {tot_lines:>6} {tot_hit:>6} {pct:>6.1f}%")
    if pct < args.min:
        print(f"[coverage-gate] FAIL: {pct:.1f}% < floor {args.min:.1f}%")
        return 1
    print(f"[coverage-gate] ok: {pct:.1f}% >= floor {args.min:.1f}%")
    return 0


if __name__ == "__main__":
    sys.exit(main())
