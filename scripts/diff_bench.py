#!/usr/bin/env python
"""Diff machine-readable bench artifacts against their baselines.

    python scripts/diff_bench.py BENCH_serving.json [BENCH_*.json ...]
           [--warn-pct 20] [--strict] [--history BENCH_HISTORY.jsonl]

The baseline for each file is the committed version at HEAD
(``git show HEAD:<file>``) — i.e. the artifact the previous PR shipped.
When HEAD carries no baseline (a brand-new suite, a rebase that dropped
the artifact), the diff falls back to the most recent rows for the same
suite in ``BENCH_HISTORY.jsonl`` (see ``scripts/bench_history.py``),
excluding the current commit so a re-run never diffs against itself.

Rows are matched by their ``config`` key; the primary metric is
``tokens_per_s`` when present (higher is better), else ``mean_s`` (lower
is better), else a suite-specific ``extra`` metric.  Regressions beyond
``--warn-pct`` are flagged.  Without ``--strict`` the script always
exits 0 (the diff is a trend signal); with ``--strict`` flagged
regressions fail, and so does a missing/unreadable artifact — CI just
ran the suite, so "no file" means the bench step itself broke and must
not pass silently.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from typing import Dict, List, Optional, Tuple

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import bench_history  # noqa: E402  (sibling script, not a package)


def _load_current(path: str) -> Optional[List[Dict]]:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return None


def _load_baseline(path: str) -> Optional[List[Dict]]:
    try:
        out = subprocess.run(["git", "show", f"HEAD:{path}"],
                             capture_output=True, text=True, check=True)
        return json.loads(out.stdout)
    except (subprocess.CalledProcessError, json.JSONDecodeError, OSError):
        return None


def _history_baseline(cur: List[Dict], history: str
                      ) -> Optional[List[Dict]]:
    """Most recent history rows for this artifact's suite (from the
    current rows' ``bench`` key), excluding the in-flight commit."""
    suites = {r.get("bench") for r in cur if r.get("bench")}
    if len(suites) != 1:
        return None
    rows = bench_history.latest_rows(suites.pop(),
                                     exclude_commit=bench_history.git_head(),
                                     path=history)
    if not rows:
        return None
    return [{"config": r["config"], "tokens_per_s": r.get("tokens_per_s",
                                                          0.0),
             "mean_s": r.get("mean_s", 0.0), "extra": r.get("extra", {})}
            for r in rows]


# one metric definition for both tools: tokens_per_s (higher better),
# else mean_s (lower better), else bench_history.EXTRA_METRICS in order
_metric = bench_history.metric_of


def diff_file(path: str, warn_pct: float,
              history: str = bench_history.HISTORY_PATH
              ) -> Tuple[int, bool]:
    """Returns (flagged regression count, artifact-missing flag)."""
    cur = _load_current(path)
    if cur is None:
        print(f"[diff] {path}: missing or unreadable — run the bench "
              "suite first (FAILS under --strict)")
        return 0, True
    base = _load_baseline(path)
    src = "HEAD"
    if base is None:
        base = _history_baseline(cur, history)
        src = f"history ({history})"
    print(f"\n## bench diff: {path}")
    if base is None:
        print(f"  no committed baseline at HEAD and no history rows "
              f"(new artifact, {len(cur)} rows) — nothing to diff")
        return 0, False
    print(f"  baseline: {src}")
    base_by = {r["config"]: r for r in base if "config" in r}
    regressions = 0
    for row in cur:
        cfgk = row.get("config")
        if cfgk is None:
            continue
        b = base_by.pop(cfgk, None)
        m = _metric(row)
        if m is None:
            print(f"  {cfgk:<28} (no comparable metric in row)")
            continue
        name, val, sense = m
        if b is None:
            print(f"  {cfgk:<28} NEW        {name}={val:.4g}")
            continue
        mb = _metric(b)
        if mb is None or mb[0] != name:
            print(f"  {cfgk:<28} metric changed "
                  f"({mb[0] if mb else 'none'} -> {name}); not compared")
            continue
        bval = mb[1]
        # near-zero baselines (e.g. ratio_err_pct == 0, perfect QoS) are
        # compared on unit scale so the delta reads in absolute points
        denom = abs(bval) if abs(bval) > 1e-9 else 1.0
        delta = (val - bval) / denom * 100.0
        worse = -delta * sense > warn_pct
        flag = "  <-- REGRESSION" if worse else ""
        regressions += int(worse)
        print(f"  {cfgk:<28} {name}: {bval:.4g} -> {val:.4g} "
              f"({delta:+.1f}%){flag}")
    for cfgk in base_by:
        print(f"  {cfgk:<28} REMOVED (was in previous artifact)")
    return regressions, False


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="+")
    ap.add_argument("--warn-pct", type=float, default=20.0,
                    help="flag regressions beyond this percentage")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero on flagged regressions AND on "
                         "missing artifacts")
    ap.add_argument("--history", default=bench_history.HISTORY_PATH,
                    help="JSONL history store used when HEAD has no "
                         "baseline for an artifact")
    args = ap.parse_args(argv)
    total = 0
    missing: List[str] = []
    for f in args.files:
        regs, miss = diff_file(f, args.warn_pct, history=args.history)
        total += regs
        if miss:
            missing.append(f)
    if total:
        print(f"\n[diff] {total} flagged regression(s) "
              f"(> {args.warn_pct:.0f}%)")
    if missing and args.strict:
        print(f"[diff] STRICT: missing artifact(s): {', '.join(missing)}")
        return 1
    return 1 if (total and args.strict) else 0


if __name__ == "__main__":
    sys.exit(main())
