#!/usr/bin/env python
"""Diff machine-readable bench artifacts against the previous PR's.

    python scripts/diff_bench.py BENCH_serving.json [BENCH_*.json ...]

The baseline for each file is the committed version at HEAD
(``git show HEAD:<file>``) — i.e. the artifact the previous PR shipped.
Rows are matched by their ``config`` key; the primary metric is
``tokens_per_s`` when present (higher is better), else ``mean_s`` (lower
is better).  Regressions beyond ``--warn-pct`` are flagged; the script
always exits 0 (artifacts move with hardware — the diff is a trend
signal, not a gate) unless ``--strict`` is given.
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
from typing import Dict, List, Optional


def _load_current(path: str) -> Optional[List[Dict]]:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return None


def _load_baseline(path: str) -> Optional[List[Dict]]:
    try:
        out = subprocess.run(["git", "show", f"HEAD:{path}"],
                             capture_output=True, text=True, check=True)
        return json.loads(out.stdout)
    except (subprocess.CalledProcessError, json.JSONDecodeError, OSError):
        return None


# fallbacks for suites whose trend metric lives under "extra" (the
# scheduler rows carry no timing — QoS error is their signal)
_EXTRA_METRICS = (("ratio_err_pct", -1), ("jain_weighted", +1))


def _metric(row: Dict) -> Optional[tuple]:
    tps = float(row.get("tokens_per_s", 0.0))
    if tps > 0:
        return "tokens_per_s", tps, +1          # higher is better
    mean = float(row.get("mean_s", 0.0))
    if mean > 0:
        return "mean_s", mean, -1               # lower is better
    extra = row.get("extra", {})
    for key, sense in _EXTRA_METRICS:
        if key in extra:
            return key, float(extra[key]), sense
    return None


def diff_file(path: str, warn_pct: float) -> int:
    cur = _load_current(path)
    if cur is None:
        print(f"[diff] {path}: missing or unreadable — run the bench "
              "suite first")
        return 0
    base = _load_baseline(path)
    print(f"\n## bench diff: {path}")
    if base is None:
        print(f"  no committed baseline at HEAD (new artifact, "
              f"{len(cur)} rows) — nothing to diff")
        return 0
    base_by = {r["config"]: r for r in base if "config" in r}
    regressions = 0
    for row in cur:
        cfgk = row.get("config")
        if cfgk is None:
            continue
        b = base_by.pop(cfgk, None)
        m = _metric(row)
        if m is None:
            print(f"  {cfgk:<28} (no comparable metric in row)")
            continue
        name, val, sense = m
        if b is None:
            print(f"  {cfgk:<28} NEW        {name}={val:.4g}")
            continue
        mb = _metric(b)
        if mb is None or mb[0] != name:
            print(f"  {cfgk:<28} metric changed "
                  f"({mb[0] if mb else 'none'} -> {name}); not compared")
            continue
        bval = mb[1]
        # near-zero baselines (e.g. ratio_err_pct == 0, perfect QoS) are
        # compared on unit scale so the delta reads in absolute points
        denom = abs(bval) if abs(bval) > 1e-9 else 1.0
        delta = (val - bval) / denom * 100.0
        worse = -delta * sense > warn_pct
        flag = "  <-- REGRESSION" if worse else ""
        regressions += int(worse)
        print(f"  {cfgk:<28} {name}: {bval:.4g} -> {val:.4g} "
              f"({delta:+.1f}%){flag}")
    for cfgk in base_by:
        print(f"  {cfgk:<28} REMOVED (was in previous artifact)")
    return regressions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="+")
    ap.add_argument("--warn-pct", type=float, default=20.0,
                    help="flag regressions beyond this percentage")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero when regressions are flagged")
    args = ap.parse_args(argv)
    total = sum(diff_file(f, args.warn_pct) for f in args.files)
    if total:
        print(f"\n[diff] {total} flagged regression(s) "
              f"(> {args.warn_pct:.0f}%)")
    return 1 if (total and args.strict) else 0


if __name__ == "__main__":
    sys.exit(main())
