#!/usr/bin/env bash
# CI entrypoint: tier-1 smoke path + quick benches + gated trend check.
#
#   scripts/ci.sh                      # smoke tests + benches + strict diff
#   FULL=1 scripts/ci.sh               # full tier-1 suite (slow tests too)
#   BENCH_ALLOW_REGRESSION=1 scripts/ci.sh
#       # override knob for *intended* regressions: the diff still prints,
#       # but flagged rows (and missing artifacts) no longer fail CI.
#       # Use it for the one PR that knowingly trades a bench off, then
#       # let the next PR re-baseline.
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${FULL:-0}" == "1" ]]; then
  python -m pytest -x -q
else
  python -m pytest -x -q -m "not slow"
fi

# smoke the live-migration demo end to end (two shells, mid-decode move,
# token-for-token continuity assert — examples/migrate_shell.py exits
# non-zero on any lost/dup/diverged completion)
python examples/migrate_shell.py

# smoke the self-healing demo (seeded IO fault + page-fault storm,
# wedged slot detected by check_health and recovered KV-intact —
# examples/fault_recovery.py exits non-zero unless the recovered
# tenant matches a fault-free oracle token for token)
python examples/fault_recovery.py

# smoke the prefix-sharing demo (templated prompts on one engine:
# asserts prefix hits, skipped prefill work, a CoW fault and >= 2x
# admitted sequences vs the private-page baseline; exits non-zero if
# sharing stops paying for itself)
python examples/prefix_sharing.py

# smoke the serving-gateway demo (Poisson mixed-SLO-tier traffic with
# continuous batching + chunked prefill, a live typed SLO rejection, a
# queued-deadline expiry, and priority aging — examples/
# gateway_serving.py exits non-zero if any of those stop holding)
python examples/gateway_serving.py

# smoke the fleet-controller demo (score-based placement, a controller-
# triggered pre-copy auto-migration off a hot member with gateway
# stream re-homing — examples/fleet_autoscale.py exits non-zero on any
# lost/duplicated stream or token divergence vs its oracle)
python examples/fleet_autoscale.py

# line-coverage gate over the migration/fleet control plane (stdlib
# trace; pytest-cov is not installable here) — the fuzz/property layer
# must keep reaching the surface it guards.  Floor = measured - 10pts.
python scripts/coverage_gate.py

# dead intra-repo links/anchors in README.md and docs/*.md fail CI —
# the docs ARE the product surface for a guide-heavy PR sequence
python scripts/check_doc_links.py

# substring match: llm_serving runs both the sweep (-> BENCH_serving.json)
# and llm_serving_scaling (Fig 10b concurrency curve); scheduler_qos,
# kernel_microbench, multislot_lanes and live_migrate write their
# BENCH_*.json artifacts
python -m benchmarks.run \
  --only llm_serving,scheduler_qos,kernel_microbench,multislot_lanes,live_migrate,prefix_sharing,fault_storm,serving_gateway,multipod_collectives,fleet_controller

# Gated trend check: diff fresh artifacts against the previous PR's
# committed versions (git show HEAD:..., falling back to
# BENCH_HISTORY.jsonl).  Per-suite noise floors; under --strict a flagged
# regression or a missing artifact fails CI.
STRICT=(--strict)
if [[ "${BENCH_ALLOW_REGRESSION:-0}" == "1" ]]; then
  STRICT=()
  echo "[ci] BENCH_ALLOW_REGRESSION=1: bench regressions will NOT fail CI"
fi
# Floors are set from MEASURED run-to-run variance, not wishes: a floor
# below a suite's own noise just manufactures red CI.
# serving: decode tokens/s moves +-35% with host load — 50% floor still
# catches a real hot-path regression (losing donation alone costs 3-6x)
python scripts/diff_bench.py BENCH_serving.json   --warn-pct 50 "${STRICT[@]}"
# scheduler: virtual-clock QoS numbers are bit-deterministic — tight 10%
python scripts/diff_bench.py BENCH_scheduler.json --warn-pct 10 "${STRICT[@]}"
# kernels: ms-scale cells swing >100% between runs on shared hosts even
# best-of-5 — the gate is an order-of-magnitude guard (e.g. silently
# falling back to interpret mode = -90%), not a perf thermometer
python scripts/diff_bench.py BENCH_kernels.json   --warn-pct 150 "${STRICT[@]}"
# multislot: trend metric is the lanes-on p99 speedup (~100-600x); the
# 90% floor only trips when lanes stop working (speedup collapses ~1x)
python scripts/diff_bench.py BENCH_multislot.json --warn-pct 90 "${STRICT[@]}"
# migrate: ms-scale downtime cells swing >2x on shared hosts (occasional
# gather/scatter retrace when the footprint shape shifts) — the 200%
# floor is an order-of-magnitude guard like the kernels suite
python scripts/diff_bench.py BENCH_migrate.json   --warn-pct 200 "${STRICT[@]}"
# prefix: the paper claims (90%-shared prefill <= 0.5x cost, capacity
# >= 2x) are HARD-ASSERTED inside bench_prefix.run() itself, so the
# trend floor only needs to catch drift in the ratio rows.  Measured
# run-to-run: prefill_speedup_x ~10-13x (+-30%), best-of-trials ms
# cells +-70% under host load — 100% floor clears the noise while still
# flagging a collapse of the speedup toward the asserted 2x minimum.
python scripts/diff_bench.py BENCH_prefix.json    --warn-pct 100 "${STRICT[@]}"
# faults: correctness (token parity vs a fault-free oracle, zero
# lost/dup completions, recoveries == rounds) is HARD-ASSERTED inside
# bench_faults.run(); the trend rows are ms-scale recovery downtime and
# bystander p99, both as host-load sensitive as the migrate suite
# (measured: recovery p99 ~240-260ms, bystander p99 0.3-3ms depending
# on storm overlap) — 200% floor = order-of-magnitude guard only
python scripts/diff_bench.py BENCH_faults.json    --warn-pct 200 "${STRICT[@]}"
# gateway: the SLO claims (continuous >= 1.3x wave goodput, chunked
# prefill >= 2x short-TTFT p99, exactly-once + oracle token parity
# under admission churn) are HARD-ASSERTED inside bench_gateway.run().
# Trend rows: goodput_x 3.3-3.7 run-to-run (+-10%), raw goodput +-20%,
# but the ms-scale chunked-TTFT p99 cells swing ~70% under host load —
# 150% floor = order-of-magnitude guard over the noisiest row
python scripts/diff_bench.py BENCH_gateway.json   --warn-pct 150 "${STRICT[@]}"
# multipod: greedy token parity across TP degrees is HARD-ASSERTED
# inside bench_multipod.run(); the trend rows are tokens/s measured in
# per-degree SUBPROCESSES (compile + 4 fake devices on shared cores),
# the noisiest timing in the suite — measured run-to-run swing up to
# ~2x, so 200% floor = order-of-magnitude guard (e.g. a decode-path
# reshard-per-step bug costs far more than 3x)
python scripts/diff_bench.py BENCH_multipod.json  --warn-pct 200 "${STRICT[@]}"
# fleet: the load-bearing claims (pre-copy p99 <= 0.25x stop-and-copy,
# controller-triggered auto-migration with oracle token parity + zero
# lost/dup streams) are HARD-ASSERTED inside bench_fleet.run(); the
# trend rows are ms-scale freeze windows, as host-load sensitive as the
# migrate suite — 200% floor = order-of-magnitude guard only
python scripts/diff_bench.py BENCH_fleet.json     --warn-pct 200 "${STRICT[@]}"

# record this run in the history store (keyed by commit+suite+config;
# re-runs on the same commit replace, never duplicate), keeping the
# last ~50 commits of history
python scripts/bench_history.py append BENCH_serving.json \
  BENCH_scheduler.json BENCH_kernels.json BENCH_multislot.json \
  BENCH_migrate.json BENCH_prefix.json BENCH_faults.json \
  BENCH_gateway.json BENCH_multipod.json BENCH_fleet.json --prune 50
