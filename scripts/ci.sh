#!/usr/bin/env bash
# CI entrypoint: tier-1 smoke path + quick serving bench.
#
#   scripts/ci.sh            # smoke tests (-m "not slow") + llm_serving bench
#   FULL=1 scripts/ci.sh     # full tier-1 suite (includes slow subprocess tests)
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${FULL:-0}" == "1" ]]; then
  python -m pytest -x -q
else
  python -m pytest -x -q -m "not slow"
fi

# substring match: runs both llm_serving (sweep -> BENCH_serving.json)
# and llm_serving_scaling (Fig 10b concurrency curve), ~40s total
python -m benchmarks.run --only llm_serving
