#!/usr/bin/env bash
# CI entrypoint: tier-1 smoke path + quick serving bench.
#
#   scripts/ci.sh            # smoke tests (-m "not slow") + llm_serving bench
#   FULL=1 scripts/ci.sh     # full tier-1 suite (includes slow subprocess tests)
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${FULL:-0}" == "1" ]]; then
  python -m pytest -x -q
else
  python -m pytest -x -q -m "not slow"
fi

# substring match: llm_serving runs both the sweep (-> BENCH_serving.json)
# and llm_serving_scaling (Fig 10b concurrency curve); scheduler_qos and
# kernel_microbench write BENCH_scheduler.json / BENCH_kernels.json
python -m benchmarks.run --only llm_serving,scheduler_qos,kernel_microbench

# trend check: diff the fresh artifacts against the previous PR's
# committed versions (git show HEAD:...).  Informational, never gating —
# pass --strict to make flagged regressions fail CI.
python scripts/diff_bench.py BENCH_serving.json BENCH_scheduler.json \
  BENCH_kernels.json
