#!/usr/bin/env python
"""Fail CI on dead intra-repo markdown links and anchors.

Checks README.md and docs/*.md:

  * relative file links (``[x](docs/api.md)``, ``[y](../src/...)``) must
    resolve to a file or directory in the repo;
  * intra-repo anchor links (``docs/architecture.md#quiesce...`` or
    ``#local-anchor``) must match a heading in the target file, using
    GitHub's slug rule (lowercase, punctuation stripped, spaces to
    dashes);
  * external links (http/https/mailto) are NOT fetched — this is a
    structure check, not a crawler.

Exit 1 listing every dead link; exit 0 quiet when clean.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
CODE_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: strip markdown/punctuation, lowercase,
    spaces -> dashes (duplicate-heading -N suffixes not modeled; none of
    our docs repeat headings)."""
    h = re.sub(r"[`*_]", "", heading.strip())
    h = re.sub(r"[^\w\- ]", "", h, flags=re.UNICODE)
    return h.lower().replace(" ", "-")


def anchors_of(path: Path) -> set:
    text = CODE_FENCE_RE.sub("", path.read_text(encoding="utf-8"))
    return {github_slug(m.group(1)) for m in HEADING_RE.finditer(text)}


def check_file(path: Path) -> list:
    errors = []
    text = CODE_FENCE_RE.sub("", path.read_text(encoding="utf-8"))
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        file_part, _, anchor = target.partition("#")
        if file_part:
            dest = (path.parent / file_part).resolve()
            if not dest.exists():
                errors.append(f"{path.relative_to(ROOT)}: dead link "
                              f"-> {target}")
                continue
        else:
            dest = path
        if anchor and dest.suffix == ".md":
            if anchor.lower() not in anchors_of(dest):
                errors.append(f"{path.relative_to(ROOT)}: dead anchor "
                              f"-> {target}")
    return errors


def main() -> int:
    files = [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))
    errors = []
    for f in files:
        if f.exists():
            errors.extend(check_file(f))
    if errors:
        print(f"[doc-links] {len(errors)} dead link(s):")
        for e in errors:
            print("  " + e)
        return 1
    print(f"[doc-links] OK ({len(files)} files)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
