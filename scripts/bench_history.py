#!/usr/bin/env python
"""Bench-history store: artifact rows keyed by (commit, suite, config).

    python scripts/bench_history.py append BENCH_serving.json [...]
    python scripts/bench_history.py trend [--suite bench_serving]
                                          [--config b8_p16_pallas0]
                                          [--last 10]

``append`` reads machine-readable bench artifacts (the
``benchmarks.common.emit_json`` schema) and appends one JSONL row per
artifact row to ``BENCH_HISTORY.jsonl``.  Re-appending for the same
(commit, suite, config) replaces the earlier row, so re-running CI on a
dirty tree never duplicates history.  ``trend`` prints a per-config
series over the last N distinct commits — the "more than one PR back"
view that ``git show HEAD:<file>`` cannot give.

``scripts/diff_bench.py`` falls back to this file when an artifact has
no committed baseline at HEAD (e.g. a brand-new suite whose artifact was
benched but not yet committed, or a rebase that dropped it).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from typing import Dict, Iterable, List, Optional

HISTORY_PATH = "BENCH_HISTORY.jsonl"

# Trend metrics living under a row's "extra" dict, in fallback order
# (sense +1 = higher is better, -1 = lower is better).  The scheduler
# rows carry no timing — QoS error is their signal; the multislot rows
# trend on the lanes-on p99 speedup.  scripts/diff_bench.py consumes
# THIS list, so both tools always agree on a row's primary metric.
EXTRA_METRICS = (("ratio_err_pct", -1), ("jain_weighted", +1),
                 ("p99_speedup_x", +1))


def metric_of(row: Dict) -> Optional[tuple]:
    """A row's primary trend metric as (name, value, sense):
    tokens_per_s, else mean_s, else the first EXTRA_METRICS hit."""
    tps = float(row.get("tokens_per_s", 0.0))
    if tps > 0:
        return "tokens_per_s", tps, +1
    mean = float(row.get("mean_s", 0.0))
    if mean > 0:
        return "mean_s", mean, -1
    extra = row.get("extra", {})
    for key, sense in EXTRA_METRICS:
        if key in extra:
            return key, float(extra[key]), sense
    return None


def git_head(default: str = "unknown") -> str:
    try:
        out = subprocess.run(["git", "rev-parse", "HEAD"],
                             capture_output=True, text=True, check=True)
        return out.stdout.strip() or default
    except (subprocess.CalledProcessError, OSError):
        return default


def load_history(path: str = HISTORY_PATH) -> List[Dict]:
    """All history rows, oldest first.  Unparseable lines are skipped —
    the store must survive a truncated write from a killed CI job."""
    rows: List[Dict] = []
    try:
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rows.append(json.loads(line))
                except json.JSONDecodeError:
                    continue
    except OSError:
        pass
    return rows


def _write_history(rows: Iterable[Dict], path: str) -> None:
    """Atomic rewrite (temp file + rename): a CI job killed mid-write
    must lose at most the in-flight update, never the whole store."""
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        for r in rows:
            f.write(json.dumps(r, default=str) + "\n")
    os.replace(tmp, path)


def append(artifacts: List[str], *, commit: Optional[str] = None,
           path: str = HISTORY_PATH) -> int:
    """Append every row of every artifact under ``commit`` (default:
    current HEAD), replacing rows with the same (commit, suite, config)."""
    commit = commit or git_head()
    existing = load_history(path)
    # a commit keeps its FIRST-seen timestamp forever: re-benching an
    # old checkout refreshes its rows without promoting it to "newest"
    # in latest_rows()
    first_ts = min((float(r.get("ts", 0.0)) for r in existing
                    if r.get("commit") == commit and r.get("ts")),
                   default=time.time())
    fresh: List[Dict] = []
    for art in artifacts:
        try:
            with open(art) as f:
                rows = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"[history] skip {art}: {e}", file=sys.stderr)
            continue
        for r in rows:
            if "config" not in r:
                continue
            fresh.append({
                "commit": commit,
                "suite": r.get("bench", art),
                "config": r["config"],
                "tokens_per_s": float(r.get("tokens_per_s", 0.0)),
                "mean_s": float(r.get("mean_s", 0.0)),
                "extra": r.get("extra", {}),
                "ts": first_ts,
            })
    if not fresh:
        print("[history] nothing to append")
        return 0
    replaced = {(r["commit"], r["suite"], r["config"]) for r in fresh}
    kept = [r for r in existing
            if (r.get("commit"), r.get("suite"), r.get("config"))
            not in replaced]
    _write_history(kept + fresh, path)
    print(f"[history] {path}: +{len(fresh)} rows for {commit[:12]} "
          f"({len(kept)} kept)")
    return 0


def latest_rows(suite: str, *, exclude_commit: Optional[str] = None,
                path: str = HISTORY_PATH) -> Optional[List[Dict]]:
    """The most recent commit's rows for a suite (``diff_bench``'s
    fallback baseline).  ``exclude_commit`` skips the in-flight commit so
    a re-run never diffs an artifact against itself."""
    rows = [r for r in load_history(path)
            if r.get("suite") == suite and r.get("commit") != exclude_commit]
    if not rows:
        return None
    # newest = max append timestamp, NOT file position: re-benching an
    # old commit rewrites its rows at the file end but must not make it
    # the baseline (rows without ts sort oldest, by file order)
    last = max(rows, key=lambda r: float(r.get("ts", 0.0)))["commit"]
    return [r for r in rows if r["commit"] == last]


def trend(*, suite: Optional[str] = None, config: Optional[str] = None,
          last: int = 10, path: str = HISTORY_PATH) -> int:
    """Per-(suite, config) metric series over the last N commits."""
    rows = load_history(path)
    if suite:
        rows = [r for r in rows if r.get("suite") == suite]
    if config:
        rows = [r for r in rows if r.get("config") == config]
    if not rows:
        print("[history] no matching rows")
        return 0
    # commit order = first-seen timestamp (stable across re-appends),
    # falling back to file position for pre-ts rows
    order: Dict[str, tuple] = {}
    for i, r in enumerate(rows):
        order.setdefault(r["commit"], (float(r.get("ts", 0.0)), i))
    commits = sorted(order, key=order.get)[-last:]
    series: Dict[tuple, Dict[str, Dict]] = {}
    for r in rows:
        if r["commit"] not in commits:
            continue
        series.setdefault((r["suite"], r["config"]), {})[r["commit"]] = r
    for (s, c), by_commit in sorted(series.items()):
        print(f"\n## {s} :: {c}")
        for commit in commits:
            r = by_commit.get(commit)
            if r is None:
                continue
            m = metric_of(r)
            val = f"{m[1]:.4g} {m[0]}" if m else "(no metric)"
            print(f"  {commit[:12]}  {val}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    sub = ap.add_subparsers(dest="cmd", required=True)
    ap_a = sub.add_parser("append", help="append artifact rows to history")
    ap_a.add_argument("artifacts", nargs="+")
    ap_a.add_argument("--commit", default=None,
                      help="override the commit key (default: HEAD)")
    ap_a.add_argument("--history", default=HISTORY_PATH)
    ap_t = sub.add_parser("trend", help="print per-config history")
    ap_t.add_argument("--suite", default=None)
    ap_t.add_argument("--config", default=None)
    ap_t.add_argument("--last", type=int, default=10,
                      help="how many commits back to show")
    ap_t.add_argument("--history", default=HISTORY_PATH)
    args = ap.parse_args(argv)
    if args.cmd == "append":
        return append(args.artifacts, commit=args.commit,
                      path=args.history)
    return trend(suite=args.suite, config=args.config, last=args.last,
                 path=args.history)


if __name__ == "__main__":
    sys.exit(main())
