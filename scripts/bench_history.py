#!/usr/bin/env python
"""Bench-history store: artifact rows keyed by (commit, suite, config).

    python scripts/bench_history.py append BENCH_serving.json [...]
                                          [--prune 50]
    python scripts/bench_history.py trend [--suite bench_serving]
                                          [--config b8_p16_pallas0]
                                          [--last 10] [--plot]
    python scripts/bench_history.py prune [--keep 50]

``append`` reads machine-readable bench artifacts (the
``benchmarks.common.emit_json`` schema) and appends one JSONL row per
artifact row to ``BENCH_HISTORY.jsonl``.  Re-appending for the same
(commit, suite, config) replaces the earlier row, so re-running CI on a
dirty tree never duplicates history.  ``trend`` prints a per-config
series over the last N distinct commits — the "more than one PR back"
view that ``git show HEAD:<file>`` cannot give.

``scripts/diff_bench.py`` falls back to this file when an artifact has
no committed baseline at HEAD (e.g. a brand-new suite whose artifact was
benched but not yet committed, or a rebase that dropped it).

``prune`` (or ``append --prune N``) bounds the store to the last N
distinct commits (by first-seen timestamp) so the JSONL file never grows
without bound; ``trend --plot`` renders each per-config series as an
ASCII sparkline for an at-a-glance regression scan.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from typing import Dict, Iterable, List, Optional

HISTORY_PATH = "BENCH_HISTORY.jsonl"

# Trend metrics living under a row's "extra" dict, in fallback order
# (sense +1 = higher is better, -1 = lower is better).  The scheduler
# rows carry no timing — QoS error is their signal; the multislot rows
# trend on the lanes-on p99 speedup.  scripts/diff_bench.py consumes
# THIS list, so both tools always agree on a row's primary metric.
EXTRA_METRICS = (("ratio_err_pct", -1), ("jain_weighted", +1),
                 ("p99_speedup_x", +1), ("prefill_speedup_x", +1),
                 ("capacity_x", +1), ("recovery_p99_ms", -1),
                 ("bystander_p99_ms", -1), ("goodput_x", +1),
                 ("ttft_speedup_x", +1), ("goodput", +1),
                 ("ttft_p99_ms", -1),
                 # fleet controller rows: the auto-migration row has no
                 # mean_s, so its freeze-window p99 is the primary trend
                 ("downtime_p99_ms", -1), ("precopy_rounds", -1))


def metric_of(row: Dict) -> Optional[tuple]:
    """A row's primary trend metric as (name, value, sense):
    tokens_per_s, else mean_s, else the first EXTRA_METRICS hit."""
    tps = float(row.get("tokens_per_s", 0.0))
    if tps > 0:
        return "tokens_per_s", tps, +1
    mean = float(row.get("mean_s", 0.0))
    if mean > 0:
        return "mean_s", mean, -1
    extra = row.get("extra", {})
    for key, sense in EXTRA_METRICS:
        if key in extra:
            return key, float(extra[key]), sense
    return None


def git_head(default: str = "unknown") -> str:
    try:
        out = subprocess.run(["git", "rev-parse", "HEAD"],
                             capture_output=True, text=True, check=True)
        return out.stdout.strip() or default
    except (subprocess.CalledProcessError, OSError):
        return default


def load_history(path: str = HISTORY_PATH) -> List[Dict]:
    """All history rows, oldest first.  Unparseable lines are skipped —
    the store must survive a truncated write from a killed CI job."""
    rows: List[Dict] = []
    try:
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rows.append(json.loads(line))
                except json.JSONDecodeError:
                    continue
    except OSError:
        pass
    return rows


def _write_history(rows: Iterable[Dict], path: str) -> None:
    """Atomic rewrite (temp file + rename): a CI job killed mid-write
    must lose at most the in-flight update, never the whole store."""
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        for r in rows:
            f.write(json.dumps(r, default=str) + "\n")
    os.replace(tmp, path)


def _commit_order(rows: List[Dict]) -> List[str]:
    """Distinct commits, oldest first: first-seen timestamp (stable
    across re-appends), falling back to file position for pre-ts rows —
    the ONE commit ordering trend/prune/latest_rows agree on."""
    order: Dict[str, tuple] = {}
    for i, r in enumerate(rows):
        if "commit" in r:
            order.setdefault(r["commit"], (float(r.get("ts", 0.0)), i))
    return sorted(order, key=order.get)


def prune(keep: int = 50, *, path: str = HISTORY_PATH) -> int:
    """Drop rows of all but the most recent ``keep`` distinct commits.
    Bounds the store (~50 commits is years of PR cadence) while keeping
    every config's full recent trend window intact."""
    rows = load_history(path)
    commits = _commit_order(rows)
    if keep <= 0 or len(commits) <= keep:
        print(f"[history] prune: {len(commits)} commit(s) <= keep={keep}, "
              "nothing to do")
        return 0
    recent = set(commits[-keep:])
    kept = [r for r in rows if r.get("commit") in recent]
    _write_history(kept, path)
    print(f"[history] pruned {len(rows) - len(kept)} row(s) from "
          f"{len(commits) - keep} old commit(s); {len(kept)} rows / "
          f"{keep} commits kept")
    return 0


def append(artifacts: List[str], *, commit: Optional[str] = None,
           path: str = HISTORY_PATH, prune_keep: int = 0) -> int:
    """Append every row of every artifact under ``commit`` (default:
    current HEAD), replacing rows with the same (commit, suite, config)."""
    commit = commit or git_head()
    existing = load_history(path)
    # a commit keeps its FIRST-seen timestamp forever: re-benching an
    # old checkout refreshes its rows without promoting it to "newest"
    # in latest_rows()
    first_ts = min((float(r.get("ts", 0.0)) for r in existing
                    if r.get("commit") == commit and r.get("ts")),
                   default=time.time())
    fresh: List[Dict] = []
    for art in artifacts:
        try:
            with open(art) as f:
                rows = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"[history] skip {art}: {e}", file=sys.stderr)
            continue
        for r in rows:
            if "config" not in r:
                continue
            fresh.append({
                "commit": commit,
                "suite": r.get("bench", art),
                "config": r["config"],
                "tokens_per_s": float(r.get("tokens_per_s", 0.0)),
                "mean_s": float(r.get("mean_s", 0.0)),
                "extra": r.get("extra", {}),
                "ts": first_ts,
            })
    if not fresh:
        print("[history] nothing to append")
        return 0
    replaced = {(r["commit"], r["suite"], r["config"]) for r in fresh}
    kept = [r for r in existing
            if (r.get("commit"), r.get("suite"), r.get("config"))
            not in replaced]
    _write_history(kept + fresh, path)
    print(f"[history] {path}: +{len(fresh)} rows for {commit[:12]} "
          f"({len(kept)} kept)")
    if prune_keep > 0:
        prune(prune_keep, path=path)
    return 0


def latest_rows(suite: str, *, exclude_commit: Optional[str] = None,
                path: str = HISTORY_PATH) -> Optional[List[Dict]]:
    """The most recent commit's rows for a suite (``diff_bench``'s
    fallback baseline).  ``exclude_commit`` skips the in-flight commit so
    a re-run never diffs an artifact against itself."""
    rows = [r for r in load_history(path)
            if r.get("suite") == suite and r.get("commit") != exclude_commit]
    if not rows:
        return None
    # newest = max append timestamp, NOT file position: re-benching an
    # old commit rewrites its rows at the file end but must not make it
    # the baseline (rows without ts sort oldest, by file order)
    last = max(rows, key=lambda r: float(r.get("ts", 0.0)))["commit"]
    return [r for r in rows if r["commit"] == last]


SPARK_BLOCKS = "▁▂▃▄▅▆▇█"


def sparkline(values: List[float]) -> str:
    """Map a series onto eight block heights (min -> ▁, max -> █); a flat
    series renders mid-height so one char still means 'data here'."""
    if not values:
        return ""
    lo, hi = min(values), max(values)
    span = hi - lo
    if span <= 0:
        return SPARK_BLOCKS[3] * len(values)
    return "".join(
        SPARK_BLOCKS[min(int((v - lo) / span * 8), 7)] for v in values)


def trend(*, suite: Optional[str] = None, config: Optional[str] = None,
          last: int = 10, plot: bool = False,
          path: str = HISTORY_PATH) -> int:
    """Per-(suite, config) metric series over the last N commits;
    ``plot=True`` adds an ASCII sparkline per series (oldest -> newest,
    annotated with the metric's min/max and whether higher is better)."""
    rows = load_history(path)
    if suite:
        rows = [r for r in rows if r.get("suite") == suite]
    if config:
        rows = [r for r in rows if r.get("config") == config]
    if not rows:
        print("[history] no matching rows")
        return 0
    commits = _commit_order(rows)[-last:]
    series: Dict[tuple, Dict[str, Dict]] = {}
    for r in rows:
        if r["commit"] not in commits:
            continue
        series.setdefault((r["suite"], r["config"]), {})[r["commit"]] = r
    for (s, c), by_commit in sorted(series.items()):
        print(f"\n## {s} :: {c}")
        points: List[tuple] = []        # (commit, name, value, sense)
        for commit in commits:
            r = by_commit.get(commit)
            if r is None:
                continue
            m = metric_of(r)
            if plot:
                if m is not None:
                    points.append((commit, *m))
                continue
            val = f"{m[1]:.4g} {m[0]}" if m else "(no metric)"
            print(f"  {commit[:12]}  {val}")
        if plot and not points:
            print("  (no comparable metric)")
            continue
        if plot and points:
            names = {p[1] for p in points}
            if len(names) != 1:
                print(f"  (metric changed across commits: "
                      f"{sorted(names)}; no sparkline)")
                continue
            vals = [p[2] for p in points]
            sense = "higher=better" if points[0][3] > 0 \
                else "lower=better"
            print(f"  {sparkline(vals)}  {points[0][1]} "
                  f"[{min(vals):.4g} .. {max(vals):.4g}] {sense}  "
                  f"({points[0][0][:8]} -> {points[-1][0][:8]}, "
                  f"{len(vals)} commits)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    sub = ap.add_subparsers(dest="cmd", required=True)
    ap_a = sub.add_parser("append", help="append artifact rows to history")
    ap_a.add_argument("artifacts", nargs="+")
    ap_a.add_argument("--commit", default=None,
                      help="override the commit key (default: HEAD)")
    ap_a.add_argument("--prune", type=int, default=0, metavar="N",
                      help="after appending, keep only the last N "
                           "distinct commits (0 = no pruning)")
    ap_a.add_argument("--history", default=HISTORY_PATH)
    ap_t = sub.add_parser("trend", help="print per-config history")
    ap_t.add_argument("--suite", default=None)
    ap_t.add_argument("--config", default=None)
    ap_t.add_argument("--last", type=int, default=10,
                      help="how many commits back to show")
    ap_t.add_argument("--plot", action="store_true",
                      help="render each series as an ASCII sparkline")
    ap_t.add_argument("--history", default=HISTORY_PATH)
    ap_p = sub.add_parser("prune",
                          help="drop history beyond the last N commits")
    ap_p.add_argument("--keep", type=int, default=50,
                      help="distinct commits to keep (default 50)")
    ap_p.add_argument("--history", default=HISTORY_PATH)
    args = ap.parse_args(argv)
    if args.cmd == "append":
        return append(args.artifacts, commit=args.commit,
                      path=args.history, prune_keep=args.prune)
    if args.cmd == "prune":
        return prune(args.keep, path=args.history)
    return trend(suite=args.suite, config=args.config, last=args.last,
                 plot=args.plot, path=args.history)


if __name__ == "__main__":
    sys.exit(main())
